//! Serialized wire format for the rank world: halo planes, session
//! commands, and distributed-reduction frames.
//!
//! Every message between endpoints is one self-describing **frame**.
//! Payload doubles travel as little-endian `f64::to_le_bytes` images, so a
//! decoded plane is **bit-identical** to the sent one: the multidomain
//! parity guarantee survives serialization. Decoding is strict — magic,
//! version, kind, enum ranges and exact lengths are all validated, because
//! a socket transport feeds this arbitrary bytes.
//!
//! The in-process [`crate::comms::transport::ChannelTransport`] ships
//! these exact bytes through channels, so the wire format is exercised on
//! every run; a socket transport writes the same frames to a TCP stream
//! (ROADMAP follow-up). The control plane (commands, partial-observable
//! sums, interior payloads, rank reports) uses the *same* framing as the
//! halo planes, so a resident session spanning real processes needs no new
//! message types — only a transport that moves bytes.
//!
//! Common prelude (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "TDPW"
//!      4     1  version (4)
//!      5     1  kind    (0 Plane, 1 Command, 2 Partials, 3 Interior,
//!                        4 Report, 5 PlaneBlock, 6 Trace)
//! ```
//!
//! Kind-specific layouts (offsets continue from the prelude):
//!
//! ```text
//! Plane      6 phase(1)  7 field(1)  8 side(1)  9 axis(1)  10 src(4)
//!            14 step(8)  22 count(4)  26 payload(8*count)
//! Command    6 op(1)  7 arg(8)          [op: 0 Advance, 1 Observables,
//!                                        2 Gather, 3 GatherPhi,
//!                                        4 Shutdown, 5 Checkpoint;
//!                                        arg = steps]
//! Partials   6 src(4)  10 steps(8)  18 sites(8)  26 mass(8)
//!            34 momentum(24)  58 phi_total(8)  66 phi_sq(8)
//!            74 wait_s(8)  82 busy_s(8)
//! Interior   6 field(1)  7 src(4)  11 count(4)  15 payload(8*count)
//!            [field: 0 F, 1 G, 2 Phi]
//! Report     6 src(4)  10 interior_sites(8)  18 steps(8)  26 compute_s(8)
//!            34 wait_s(8)  42 idle_s(8)  50 bytes_sent(8)  58 msgs_sent(8)
//!            66 bytes_axis(24)  90 msgs_axis(24)  114 super_steps(8)
//!            122 bytes_intra(8)  130 bytes_inter(8)  138 msgs_intra(8)
//!            146 msgs_inter(8)
//! PlaneBlock 6 field(1)  7 side(1)  8 axis(1)  9 depth(4)  13 src(4)
//!            17 step(8)  25 count(4)  29 payload(8*count)
//! Trace      6 src(4)  10 count(4)  14 records(31*count)
//!            record: 0 phase(1)  1 axis(1)  2 side(1)  3 tid(4)
//!                    7 step(8)  15 t_start(8)  23 t_end(8)
//!            [phase: obs::TracePhase discriminant 0..=11; axis 0/1/2 or
//!             255 = none; side 0 low / 1 high or 255 = none; t_* are
//!             f64 seconds since the sending rank's epoch]
//! ```
//!
//! Version 3 added the `axis` byte (0 x, 1 y, 2 z) to `Plane` and
//! `PlaneBlock`: a 3D Cartesian rank grid exchanges faces along up to
//! three axes per step, and a rank with only two grid neighbours along
//! an axis pair (a 2-wide axis) needs `(side, axis)` to disambiguate the
//! two frames the *same* peer sends it. Slab worlds always send
//! `axis = 0`.
//!
//! Version 4 is the telemetry revision: `Report` grew per-axis halo
//! byte/message counters and the super-step count, `Partials` grew the
//! running wait/busy seconds (the driver heartbeat's wait fraction), and
//! the `Trace` frame ships a rank's span timeline
//! ([`crate::obs::trace::SpanRecorder`]) to the driver at `Shutdown` —
//! a tracing rank sends its `Trace` immediately *before* its `Report`,
//! so the per-sender ordering guarantee means the driver's report
//! collection loop sees every timeline by the time the last report
//! lands. Tracing-off runs never send a `Trace` frame.
//!
//! Version 5 is the hybrid-world revision: `Report` grew the per-link
//! traffic split — halo bytes/messages carried over **intra-host**
//! links (in-process channels inside a hybrid host process, or the
//! 1-rank periodic self-seam) vs **inter-host** links (TCP sockets).
//! `bytes_intra + bytes_inter == bytes_sent` and likewise for messages;
//! a pure-socket world counts everything inter (even co-hosted loopback
//! links — that full serialize/syscall cost is exactly what the hybrid
//! transport removes), a pure-channel world counts everything intra.
//!
//! Version 6 is the checkpoint revision: `Command` grew op 5,
//! `Checkpoint` — the driver's request for a full sub-domain state
//! snapshot. A rank answers exactly like `Gather` (its interior `f`
//! then `g` as [`InteriorMsg`] frames, bit-exact LE doubles), but the
//! distinct op lets the driver checkpoint mid-run without disturbing
//! observable or gather bookkeeping, and gives supervised restart a
//! frame to pin in tests. The gathered global state is what
//! [`crate::comms::checkpoint`] serializes to disk.
//!
//! `PlaneBlock` is the communication-avoiding super-step frame: one
//! message carries a whole `depth`-plane-deep ghost block (the
//! `halo::pack_x_planes` layout), replacing `depth` individual `Plane`
//! frames — one TCP write per super-step per (field, side) instead of
//! per step per plane. Super-steps run on slab grids, so its axis is
//! always `Axis::X` today; the byte keeps the two face-frame headers
//! congruent.

use crate::error::{Error, Result};
use crate::obs::trace::{Span, TracePhase, AXIS_NONE, SIDE_NONE};

/// Frame magic: "targetDP wire".
pub const MAGIC: [u8; 4] = *b"TDPW";
/// Wire format version (6: checkpoint/restart — the `Checkpoint`
/// session command).
pub const VERSION: u8 = 6;
/// Fixed header size of a [`PlaneMsg`] frame in bytes.
pub const PLANE_HEADER_LEN: usize = 26;
/// Fixed header size of an [`InteriorMsg`] frame in bytes.
pub const INTERIOR_HEADER_LEN: usize = 15;
/// Fixed header size of a [`PlaneBlockMsg`] frame in bytes.
pub const PLANE_BLOCK_HEADER_LEN: usize = 29;
/// Fixed header size of a [`TraceMsg`] frame in bytes.
pub const TRACE_HEADER_LEN: usize = 14;
/// Encoded size of one span record inside a [`TraceMsg`] frame.
pub const TRACE_RECORD_LEN: usize = 31;

const KIND_PLANE: u8 = 0;
const KIND_COMMAND: u8 = 1;
const KIND_PARTIALS: u8 = 2;
const KIND_INTERIOR: u8 = 3;
const KIND_REPORT: u8 = 4;
const KIND_PLANE_BLOCK: u8 = 5;
const KIND_TRACE: u8 = 6;

/// Which of the two per-step exchanges a plane belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Pre-collision exchange of post-stream `g` boundary planes — feeds
    /// the phi moment / gradient stencil at the subdomain edge.
    Moments = 0,
    /// Pre-stream exchange of post-collision `f` and `g` boundary planes
    /// — feeds the pull-streaming of the edge destination planes.
    Stream = 1,
}

/// Which distribution field a plane carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FieldId {
    F = 0,
    G = 1,
}

/// Which halo plane the payload fills **at the receiver**: `Low` arrives
/// from the left neighbour (its high boundary plane), `High` from the
/// right.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Side {
    Low = 0,
    High = 1,
}

/// Which lattice axis a face frame crosses — the staged x→y→z exchange
/// of a 3D Cartesian rank grid tags each face with its axis, because a
/// 2-wide grid axis makes both of a rank's frames along it arrive from
/// the *same* peer and `(side, axis)` is what tells them apart. Slab
/// worlds always send [`Axis::X`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Axis {
    X = 0,
    Y = 1,
    Z = 2,
}

impl Axis {
    /// The three axes in staged exchange order.
    pub const ALL: [Axis; 3] = [Axis::X, Axis::Y, Axis::Z];

    /// Axis for a 0/1/2 lattice-axis index (panics outside 0..3).
    pub fn from_index(a: usize) -> Axis {
        Self::ALL[a]
    }

    /// Lattice-axis index (0 = x, 1 = y, 2 = z).
    pub fn index(self) -> usize {
        self as usize
    }
}

/// Message envelope: the MPI `(tag)` analog the receiver matches on.
/// Unique per (step, exchange phase, field, halo side, axis), so
/// out-of-order arrival — a neighbour running up to a step ahead, or the
/// same peer sending both sides of a 2-wide grid axis — is unambiguous.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tag {
    /// Timestep the plane belongs to.
    pub step: u64,
    /// Which of the two per-step exchanges.
    pub phase: Phase,
    /// Which distribution field the payload carries.
    pub field: FieldId,
    /// Which halo plane the payload fills at the receiver.
    pub side: Side,
    /// Which lattice axis the face crosses.
    pub axis: Axis,
}

/// One halo plane in flight: envelope + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct PlaneMsg {
    /// Sending rank (diagnostics; matching is by [`Tag`]).
    pub src: u32,
    /// The envelope the receiver matches on.
    pub tag: Tag,
    /// `ncomp * plane_sites` doubles, SoA component-major (the
    /// `halo::pack_x_plane` layout).
    pub data: Vec<f64>,
}

/// A depth-tagged multi-plane ghost block in flight: one frame carrying
/// `depth` consecutive halo planes of one field for one side — the
/// communication-avoiding super-step exchange unit. The receiver matches
/// on `(step, field, side)` where `step` is the global timestep at the
/// start of the super-step, and validates `depth` against its own plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlaneBlockMsg {
    /// Sending rank (diagnostics; matching is by `(step, field, side)`).
    pub src: u32,
    /// Global timestep at the start of the super-step the block feeds.
    pub step: u64,
    /// Which distribution field the payload carries.
    pub field: FieldId,
    /// Which ghost region the payload fills at the receiver.
    pub side: Side,
    /// Which lattice axis the block crosses (always [`Axis::X`] today:
    /// super-steps run on slab grids).
    pub axis: Axis,
    /// Number of consecutive x-planes in the block.
    pub depth: u32,
    /// `ncomp * depth * plane_sites` doubles, SoA component-major with
    /// the `depth` planes contiguous per component (the
    /// `halo::pack_x_planes` layout).
    pub data: Vec<f64>,
}

/// Driver → rank session command. Broadcast by the controller; each rank
/// executes commands strictly in arrival order (the transport's
/// per-sender-pair ordering guarantee), so no sequence numbers are
/// needed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Command {
    /// Step the local slab `steps` more timesteps.
    Advance { steps: u64 },
    /// Reply with a [`PartialObs`] reduction of the current interior.
    Observables,
    /// Reply with two [`InteriorMsg`] frames: the interior `f` then `g`.
    Gather,
    /// Reply with one [`InteriorMsg`] frame carrying the interior phi
    /// field (recomputed from the current `g` with the rank's own pool).
    GatherPhi,
    /// Send a final [`ReportMsg`] and exit the rank thread.
    Shutdown,
    /// Reply exactly like [`Command::Gather`] — interior `f` then `g` as
    /// [`InteriorMsg`] frames — but as a checkpoint snapshot request, so
    /// the driver can persist a decomposition-independent restart image
    /// between logging blocks ([`crate::comms::checkpoint`]).
    Checkpoint,
}

/// Rank → driver partial observable sums over this rank's interior.
/// Exact per-rank sums; the controller combines them in rank order, so
/// the result is deterministic (though the summation order differs from a
/// single global sweep — see `Observables::from_sums`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartialObs {
    /// Reporting rank.
    pub src: u32,
    /// Steps completed when the reduction ran (protocol sanity check).
    pub steps: u64,
    /// Interior sites reduced over.
    pub sites: u64,
    /// Sum of all f components over interior sites.
    pub mass: f64,
    /// Velocity-weighted f sums over interior sites.
    pub momentum: [f64; 3],
    /// Sum of all g components (= sum of phi) over interior sites.
    pub phi_total: f64,
    /// Sum of phi^2 over interior sites (for the variance).
    pub phi_sq: f64,
    /// Wall seconds this rank has spent blocked on halo messages so far
    /// (a running snapshot of the final report's `wait_s` — feeds the
    /// driver's `--heartbeat` wait fraction between blocks).
    pub wait_s: f64,
    /// Wall seconds of *working* time so far: compute + wait, idle at
    /// the command barrier excluded. `wait_s / busy_s` is the rank's
    /// running wait fraction.
    pub busy_s: f64,
}

/// Which field an [`InteriorMsg`] carries (distinct from the plane
/// [`FieldId`] because gathers also move the derived phi field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InteriorField {
    F = 0,
    G = 1,
    Phi = 2,
}

/// Rank → driver interior payload: the rank's owned planes of one field,
/// SoA component-major, halos excluded (`ncomp * lxl * plane` doubles).
#[derive(Debug, Clone, PartialEq)]
pub struct InteriorMsg {
    /// Sending rank — routes the payload to its global slab offset.
    pub src: u32,
    /// Which field the payload is.
    pub field: InteriorField,
    /// The packed interior planes, SoA component-major.
    pub data: Vec<f64>,
}

/// Rank → driver final timing/traffic report (sent on `Shutdown`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReportMsg {
    /// Reporting rank.
    pub src: u32,
    /// Sites this rank owned (halo planes excluded).
    pub interior_sites: u64,
    /// Timesteps completed over the rank's lifetime.
    pub steps: u64,
    /// Wall seconds computing (total minus wait and idle).
    pub compute_s: f64,
    /// Wall seconds blocked waiting for halo planes.
    pub wait_s: f64,
    /// Wall seconds parked at the command barrier.
    pub idle_s: f64,
    /// Halo bytes sent over the rank's lifetime.
    pub bytes_sent: u64,
    /// Halo plane messages sent over the rank's lifetime.
    pub msgs_sent: u64,
    /// `bytes_sent` split by exchange axis (x, y, z; the per-axis
    /// entries sum to the total — an undecomposed axis stays 0).
    pub bytes_axis: [u64; 3],
    /// `msgs_sent` split by exchange axis (sums to the total).
    pub msgs_axis: [u64; 3],
    /// Communication-avoiding super-steps executed (0 on depth-1
    /// schedules; each super-step covers up to `depth` timesteps).
    pub super_steps: u64,
    /// `bytes_sent` carried over intra-host links (in-process channels
    /// in a hybrid world, or the 1-rank periodic self-seam). Sums with
    /// `bytes_inter` to `bytes_sent`.
    pub bytes_intra: u64,
    /// `bytes_sent` carried over inter-host links (TCP sockets). A
    /// pure-socket world counts everything here, even co-hosted
    /// loopback links.
    pub bytes_inter: u64,
    /// `msgs_sent` carried over intra-host links (sums with
    /// `msgs_inter` to `msgs_sent`).
    pub msgs_intra: u64,
    /// `msgs_sent` carried over inter-host links.
    pub msgs_inter: u64,
}

/// Rank → driver span timeline (sent on `Shutdown`, immediately before
/// the [`ReportMsg`], and only when the run traced). Timestamps are
/// seconds since the *sending rank's* epoch — timelines from different
/// ranks are not mutually ordered (socket ranks are separate processes),
/// which is why the trace export keeps one pid per rank.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceMsg {
    /// Reporting rank.
    pub src: u32,
    /// The rank's recorded spans: the rank thread's (tid 0) followed by
    /// each TLP worker's (tid = worker + 1), each group oldest-first.
    pub spans: Vec<Span>,
}

/// Any frame on the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Plane(PlaneMsg),
    Command(Command),
    Partials(PartialObs),
    Interior(InteriorMsg),
    Report(ReportMsg),
    PlaneBlock(PlaneBlockMsg),
    Trace(TraceMsg),
}

fn prelude(out: &mut Vec<u8>, kind: u8) {
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind);
}

/// Whether an encoded frame is a rank [`ReportMsg`] — a header peek, no
/// decode. The hybrid transport's driver-side link readers use this to
/// tell a normal post-report host-process exit (every resident rank's
/// report already crossed the link) from a mid-run host death.
pub(crate) fn is_report_frame(bytes: &[u8]) -> bool {
    bytes.len() >= 6 && bytes[..4] == MAGIC && bytes[5] == KIND_REPORT
}

fn push_f64s(out: &mut Vec<u8>, data: &[f64]) {
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

impl PlaneMsg {
    /// Encoded frame size for a payload of `count` doubles.
    pub fn frame_len(count: usize) -> usize {
        PLANE_HEADER_LEN + 8 * count
    }

    /// Serialize to the wire frame.
    pub fn encode(&self) -> Vec<u8> {
        Self::encode_from(self.src, self.tag, &self.data)
    }

    /// Build the wire frame straight from a borrowed payload — the
    /// zero-intermediate-copy form the send hot path uses (no `PlaneMsg`
    /// with an owned `Vec<f64>` needs to exist on the sender side).
    pub fn encode_from(src: u32, tag: Tag, data: &[f64]) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::frame_len(data.len()));
        prelude(&mut out, KIND_PLANE);
        out.push(tag.phase as u8);
        out.push(tag.field as u8);
        out.push(tag.side as u8);
        out.push(tag.axis as u8);
        out.extend_from_slice(&src.to_le_bytes());
        out.extend_from_slice(&tag.step.to_le_bytes());
        out.extend_from_slice(&(data.len() as u32).to_le_bytes());
        push_f64s(&mut out, data);
        out
    }

    /// Parse a wire frame, requiring it to be a halo plane.
    pub fn decode(bytes: &[u8]) -> Result<PlaneMsg> {
        match Frame::decode(bytes)? {
            Frame::Plane(msg) => Ok(msg),
            other => Err(Error::Invalid(format!(
                "comms wire: expected a halo plane, got {other:?}"
            ))),
        }
    }
}

impl PlaneBlockMsg {
    /// Encoded frame size for a payload of `count` doubles.
    pub fn frame_len(count: usize) -> usize {
        PLANE_BLOCK_HEADER_LEN + 8 * count
    }

    /// Serialize to the wire frame.
    pub fn encode(&self) -> Vec<u8> {
        Self::encode_from(self.src, self.step, self.field, self.side,
                          self.axis, self.depth, &self.data)
    }

    /// Build the wire frame straight from a borrowed payload — the
    /// zero-intermediate-copy form the super-step send path uses.
    pub fn encode_from(
        src: u32,
        step: u64,
        field: FieldId,
        side: Side,
        axis: Axis,
        depth: u32,
        data: &[f64],
    ) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::frame_len(data.len()));
        prelude(&mut out, KIND_PLANE_BLOCK);
        out.push(field as u8);
        out.push(side as u8);
        out.push(axis as u8);
        out.extend_from_slice(&depth.to_le_bytes());
        out.extend_from_slice(&src.to_le_bytes());
        out.extend_from_slice(&step.to_le_bytes());
        out.extend_from_slice(&(data.len() as u32).to_le_bytes());
        push_f64s(&mut out, data);
        out
    }
}

impl Command {
    fn encode(&self) -> Vec<u8> {
        let (op, arg): (u8, u64) = match *self {
            Command::Advance { steps } => (0, steps),
            Command::Observables => (1, 0),
            Command::Gather => (2, 0),
            Command::GatherPhi => (3, 0),
            Command::Shutdown => (4, 0),
            Command::Checkpoint => (5, 0),
        };
        let mut out = Vec::with_capacity(15);
        prelude(&mut out, KIND_COMMAND);
        out.push(op);
        out.extend_from_slice(&arg.to_le_bytes());
        out
    }
}

impl InteriorMsg {
    fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(INTERIOR_HEADER_LEN + 8 * self.data.len());
        prelude(&mut out, KIND_INTERIOR);
        out.push(self.field as u8);
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&(self.data.len() as u32).to_le_bytes());
        push_f64s(&mut out, &self.data);
        out
    }
}

impl PartialObs {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(90);
        prelude(&mut out, KIND_PARTIALS);
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&self.steps.to_le_bytes());
        out.extend_from_slice(&self.sites.to_le_bytes());
        out.extend_from_slice(&self.mass.to_le_bytes());
        push_f64s(&mut out, &self.momentum);
        out.extend_from_slice(&self.phi_total.to_le_bytes());
        out.extend_from_slice(&self.phi_sq.to_le_bytes());
        out.extend_from_slice(&self.wait_s.to_le_bytes());
        out.extend_from_slice(&self.busy_s.to_le_bytes());
        out
    }
}

impl ReportMsg {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(154);
        prelude(&mut out, KIND_REPORT);
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&self.interior_sites.to_le_bytes());
        out.extend_from_slice(&self.steps.to_le_bytes());
        out.extend_from_slice(&self.compute_s.to_le_bytes());
        out.extend_from_slice(&self.wait_s.to_le_bytes());
        out.extend_from_slice(&self.idle_s.to_le_bytes());
        out.extend_from_slice(&self.bytes_sent.to_le_bytes());
        out.extend_from_slice(&self.msgs_sent.to_le_bytes());
        for v in &self.bytes_axis {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.msgs_axis {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.super_steps.to_le_bytes());
        out.extend_from_slice(&self.bytes_intra.to_le_bytes());
        out.extend_from_slice(&self.bytes_inter.to_le_bytes());
        out.extend_from_slice(&self.msgs_intra.to_le_bytes());
        out.extend_from_slice(&self.msgs_inter.to_le_bytes());
        out
    }
}

impl TraceMsg {
    /// Encoded frame size for `count` span records.
    pub fn frame_len(count: usize) -> usize {
        TRACE_HEADER_LEN + TRACE_RECORD_LEN * count
    }

    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::frame_len(self.spans.len()));
        prelude(&mut out, KIND_TRACE);
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&(self.spans.len() as u32).to_le_bytes());
        for s in &self.spans {
            out.push(s.phase as u8);
            out.push(s.axis);
            out.push(s.side);
            out.extend_from_slice(&s.tid.to_le_bytes());
            out.extend_from_slice(&s.step.to_le_bytes());
            out.extend_from_slice(&s.t_start.to_le_bytes());
            out.extend_from_slice(&s.t_end.to_le_bytes());
        }
        out
    }
}

/// Strict bounds-checked reader over a received frame.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => Err(Error::Invalid(format!(
                "comms wire: frame truncated at byte {} (want {n} more \
                 of {})",
                self.pos,
                self.buf.len()
            ))),
        }
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Exactly `count` doubles which must exhaust the frame.
    fn f64_tail(&mut self, count: usize) -> Result<Vec<f64>> {
        let want = count.checked_mul(8).ok_or_else(|| {
            Error::Invalid("comms wire: payload count overflows".into())
        })?;
        if self.buf.len() - self.pos != want {
            return Err(Error::Invalid(format!(
                "comms wire: length {} != header + {count} doubles",
                self.buf.len()
            )));
        }
        let data = self.take(want)?;
        Ok(data
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// The frame must end exactly here.
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::Invalid(format!(
                "comms wire: {} trailing bytes after a complete frame",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

impl Frame {
    /// Serialize any frame to its wire bytes.
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Frame::Plane(m) => m.encode(),
            Frame::Command(c) => c.encode(),
            Frame::Partials(p) => p.encode(),
            Frame::Interior(i) => i.encode(),
            Frame::Report(r) => r.encode(),
            Frame::PlaneBlock(b) => b.encode(),
            Frame::Trace(t) => t.encode(),
        }
    }

    /// Parse a wire frame (strict: magic, version, kind, enum ranges and
    /// exact length are all validated — a socket transport feeds this
    /// arbitrary bytes).
    pub fn decode(bytes: &[u8]) -> Result<Frame> {
        let bad = |m: String| Error::Invalid(format!("comms wire: {m}"));
        let mut r = Reader::new(bytes);
        if r.take(4)? != &MAGIC[..] {
            return Err(bad(format!("bad magic {:02x?}", &bytes[..4])));
        }
        let version = r.u8()?;
        if version != VERSION {
            return Err(bad(format!("version {version} (want {VERSION})")));
        }
        match r.u8()? {
            KIND_PLANE => {
                let phase = match r.u8()? {
                    0 => Phase::Moments,
                    1 => Phase::Stream,
                    v => return Err(bad(format!("unknown phase {v}"))),
                };
                let field = match r.u8()? {
                    0 => FieldId::F,
                    1 => FieldId::G,
                    v => return Err(bad(format!("unknown field {v}"))),
                };
                let side = match r.u8()? {
                    0 => Side::Low,
                    1 => Side::High,
                    v => return Err(bad(format!("unknown side {v}"))),
                };
                let axis = match r.u8()? {
                    0 => Axis::X,
                    1 => Axis::Y,
                    2 => Axis::Z,
                    v => return Err(bad(format!("unknown axis {v}"))),
                };
                let src = r.u32()?;
                let step = r.u64()?;
                let count = r.u32()? as usize;
                let data = r.f64_tail(count)?;
                Ok(Frame::Plane(PlaneMsg {
                    src,
                    tag: Tag { step, phase, field, side, axis },
                    data,
                }))
            }
            KIND_COMMAND => {
                let op = r.u8()?;
                let arg = r.u64()?;
                r.done()?;
                let cmd = match op {
                    0 => Command::Advance { steps: arg },
                    1 => Command::Observables,
                    2 => Command::Gather,
                    3 => Command::GatherPhi,
                    4 => Command::Shutdown,
                    5 => Command::Checkpoint,
                    v => return Err(bad(format!("unknown command {v}"))),
                };
                Ok(Frame::Command(cmd))
            }
            KIND_PARTIALS => {
                let src = r.u32()?;
                let steps = r.u64()?;
                let sites = r.u64()?;
                let mass = r.f64()?;
                let momentum = [r.f64()?, r.f64()?, r.f64()?];
                let phi_total = r.f64()?;
                let phi_sq = r.f64()?;
                let wait_s = r.f64()?;
                let busy_s = r.f64()?;
                r.done()?;
                Ok(Frame::Partials(PartialObs {
                    src,
                    steps,
                    sites,
                    mass,
                    momentum,
                    phi_total,
                    phi_sq,
                    wait_s,
                    busy_s,
                }))
            }
            KIND_INTERIOR => {
                let field = match r.u8()? {
                    0 => InteriorField::F,
                    1 => InteriorField::G,
                    2 => InteriorField::Phi,
                    v => {
                        return Err(bad(format!(
                            "unknown interior field {v}"
                        )))
                    }
                };
                let src = r.u32()?;
                let count = r.u32()? as usize;
                let data = r.f64_tail(count)?;
                Ok(Frame::Interior(InteriorMsg { src, field, data }))
            }
            KIND_REPORT => {
                let src = r.u32()?;
                let interior_sites = r.u64()?;
                let steps = r.u64()?;
                let compute_s = r.f64()?;
                let wait_s = r.f64()?;
                let idle_s = r.f64()?;
                let bytes_sent = r.u64()?;
                let msgs_sent = r.u64()?;
                let bytes_axis = [r.u64()?, r.u64()?, r.u64()?];
                let msgs_axis = [r.u64()?, r.u64()?, r.u64()?];
                let super_steps = r.u64()?;
                let bytes_intra = r.u64()?;
                let bytes_inter = r.u64()?;
                let msgs_intra = r.u64()?;
                let msgs_inter = r.u64()?;
                r.done()?;
                Ok(Frame::Report(ReportMsg {
                    src,
                    interior_sites,
                    steps,
                    compute_s,
                    wait_s,
                    idle_s,
                    bytes_sent,
                    msgs_sent,
                    bytes_axis,
                    msgs_axis,
                    super_steps,
                    bytes_intra,
                    bytes_inter,
                    msgs_intra,
                    msgs_inter,
                }))
            }
            KIND_PLANE_BLOCK => {
                let field = match r.u8()? {
                    0 => FieldId::F,
                    1 => FieldId::G,
                    v => return Err(bad(format!("unknown field {v}"))),
                };
                let side = match r.u8()? {
                    0 => Side::Low,
                    1 => Side::High,
                    v => return Err(bad(format!("unknown side {v}"))),
                };
                let axis = match r.u8()? {
                    0 => Axis::X,
                    1 => Axis::Y,
                    2 => Axis::Z,
                    v => return Err(bad(format!("unknown axis {v}"))),
                };
                let depth = r.u32()?;
                let src = r.u32()?;
                let step = r.u64()?;
                let count = r.u32()? as usize;
                let data = r.f64_tail(count)?;
                Ok(Frame::PlaneBlock(PlaneBlockMsg {
                    src,
                    step,
                    field,
                    side,
                    axis,
                    depth,
                    data,
                }))
            }
            KIND_TRACE => {
                let src = r.u32()?;
                let count = r.u32()? as usize;
                let want = count.checked_mul(TRACE_RECORD_LEN)
                    .ok_or_else(|| bad("span count overflows".into()))?;
                if bytes.len() != TRACE_HEADER_LEN + want {
                    return Err(bad(format!(
                        "length {} != header + {count} span records",
                        bytes.len()
                    )));
                }
                let mut spans = Vec::with_capacity(count);
                for _ in 0..count {
                    let phase = r.u8()?;
                    let phase = TracePhase::from_u8(phase).ok_or_else(
                        || bad(format!("unknown trace phase {phase}")),
                    )?;
                    let axis = r.u8()?;
                    if axis > 2 && axis != AXIS_NONE {
                        return Err(bad(format!(
                            "unknown span axis {axis}"
                        )));
                    }
                    let side = r.u8()?;
                    if side > 1 && side != SIDE_NONE {
                        return Err(bad(format!(
                            "unknown span side {side}"
                        )));
                    }
                    let tid = r.u32()?;
                    let step = r.u64()?;
                    let t_start = r.f64()?;
                    let t_end = r.f64()?;
                    spans.push(Span { phase, step, axis, side, tid,
                                      t_start, t_end });
                }
                r.done()?;
                Ok(Frame::Trace(TraceMsg { src, spans }))
            }
            v => Err(bad(format!("unknown frame kind {v}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PlaneMsg {
        PlaneMsg {
            src: 3,
            tag: Tag {
                step: 41,
                phase: Phase::Stream,
                field: FieldId::G,
                side: Side::High,
                axis: Axis::Y,
            },
            data: vec![0.0, -1.5, f64::MIN_POSITIVE, 1.0 / 3.0, -0.0,
                       f64::MAX, 1e-300],
        }
    }

    #[test]
    fn plane_round_trip_is_bit_exact() {
        let msg = sample();
        let back = PlaneMsg::decode(&msg.encode()).unwrap();
        assert_eq!(back.src, msg.src);
        assert_eq!(back.tag, msg.tag);
        assert_eq!(back.data.len(), msg.data.len());
        for (a, b) in back.data.iter().zip(&msg.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "bitwise f64 transport");
        }
    }

    #[test]
    fn empty_payload_round_trips() {
        let msg = PlaneMsg {
            src: 0,
            tag: Tag {
                step: 0,
                phase: Phase::Moments,
                field: FieldId::F,
                side: Side::Low,
                axis: Axis::X,
            },
            data: vec![],
        };
        let bytes = msg.encode();
        assert_eq!(bytes.len(), PLANE_HEADER_LEN);
        assert_eq!(PlaneMsg::decode(&bytes).unwrap(), msg);
    }

    #[test]
    fn command_frames_round_trip() {
        for cmd in [Command::Advance { steps: 7 },
                    Command::Advance { steps: u64::MAX },
                    Command::Observables,
                    Command::Gather,
                    Command::GatherPhi,
                    Command::Shutdown,
                    Command::Checkpoint] {
            let fr = Frame::Command(cmd);
            assert_eq!(Frame::decode(&fr.encode()).unwrap(), fr, "{cmd:?}");
        }
    }

    #[test]
    fn partials_frame_round_trips_bitwise() {
        let p = PartialObs {
            src: 2,
            steps: 999,
            sites: 12_345,
            mass: 1.0 / 3.0,
            momentum: [-0.0, f64::MIN_POSITIVE, 7.25e11],
            phi_total: -41.5,
            phi_sq: 1e-300,
            wait_s: 0.0625,
            busy_s: 1.0 / 7.0,
        };
        let fr = Frame::Partials(p);
        match Frame::decode(&fr.encode()).unwrap() {
            Frame::Partials(back) => {
                assert_eq!(back.src, p.src);
                assert_eq!(back.steps, p.steps);
                assert_eq!(back.sites, p.sites);
                assert_eq!(back.mass.to_bits(), p.mass.to_bits());
                for (a, b) in back.momentum.iter().zip(&p.momentum) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert_eq!(back.phi_total.to_bits(), p.phi_total.to_bits());
                assert_eq!(back.phi_sq.to_bits(), p.phi_sq.to_bits());
                assert_eq!(back.wait_s.to_bits(), p.wait_s.to_bits());
                assert_eq!(back.busy_s.to_bits(), p.busy_s.to_bits());
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn interior_and_report_frames_round_trip() {
        let i = InteriorMsg {
            src: 1,
            field: InteriorField::Phi,
            data: vec![0.5, -0.5, 1e-12],
        };
        let fr = Frame::Interior(i.clone());
        assert_eq!(Frame::decode(&fr.encode()).unwrap(), fr);
        assert_eq!(fr.encode().len(),
                   INTERIOR_HEADER_LEN + 8 * i.data.len());

        let r = ReportMsg {
            src: 3,
            interior_sites: 4096,
            steps: 100,
            compute_s: 1.25,
            wait_s: 0.5,
            idle_s: 0.125,
            bytes_sent: 1 << 20,
            msgs_sent: 600,
            bytes_axis: [1 << 19, 1 << 18, (1 << 20) - (1 << 19)
                         - (1 << 18)],
            msgs_axis: [200, 300, 100],
            super_steps: 50,
            bytes_intra: 1 << 19,
            bytes_inter: (1 << 20) - (1 << 19),
            msgs_intra: 400,
            msgs_inter: 200,
        };
        let fr = Frame::Report(r);
        assert_eq!(Frame::decode(&fr.encode()).unwrap(), fr);
    }

    fn sample_block() -> PlaneBlockMsg {
        PlaneBlockMsg {
            src: 2,
            step: 12,
            field: FieldId::F,
            side: Side::Low,
            axis: Axis::X,
            depth: 4,
            data: vec![0.0, -1.5, f64::MIN_POSITIVE, 1.0 / 3.0, -0.0,
                       f64::MAX, 1e-300, 42.0],
        }
    }

    #[test]
    fn plane_block_round_trip_is_bit_exact() {
        let msg = sample_block();
        let bytes = msg.encode();
        assert_eq!(bytes.len(),
                   PLANE_BLOCK_HEADER_LEN + 8 * msg.data.len());
        match Frame::decode(&bytes).unwrap() {
            Frame::PlaneBlock(back) => {
                assert_eq!(back.src, msg.src);
                assert_eq!(back.step, msg.step);
                assert_eq!(back.field, msg.field);
                assert_eq!(back.side, msg.side);
                assert_eq!(back.axis, msg.axis);
                assert_eq!(back.depth, msg.depth);
                assert_eq!(back.data.len(), msg.data.len());
                for (a, b) in back.data.iter().zip(&msg.data) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "bitwise f64 transport");
                }
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn empty_plane_block_round_trips() {
        let msg = PlaneBlockMsg {
            src: 0,
            step: 0,
            field: FieldId::G,
            side: Side::High,
            axis: Axis::X,
            depth: 0,
            data: vec![],
        };
        let bytes = msg.encode();
        assert_eq!(bytes.len(), PLANE_BLOCK_HEADER_LEN);
        assert_eq!(Frame::decode(&bytes).unwrap(), Frame::PlaneBlock(msg));
    }

    #[test]
    fn corrupt_plane_blocks_rejected() {
        let good = sample_block().encode();
        // field out of range
        let mut bad = good.clone();
        bad[6] = 7;
        assert!(Frame::decode(&bad).is_err());
        // side out of range
        let mut bad = good.clone();
        bad[7] = 2;
        assert!(Frame::decode(&bad).is_err());
        // axis out of range
        let mut bad = good.clone();
        bad[8] = 3;
        assert!(Frame::decode(&bad).is_err());
        // payload length mismatch
        let mut bad = good.clone();
        bad.pop();
        assert!(Frame::decode(&bad).is_err());
        // declared count larger than payload
        let mut bad = good.clone();
        bad[25] = bad[25].wrapping_add(1);
        assert!(Frame::decode(&bad).is_err());
        // truncated header
        assert!(Frame::decode(&good[..20]).is_err());
        // a block frame is rejected by the single-plane decoder
        assert!(PlaneMsg::decode(&good).is_err());
    }

    #[test]
    fn corrupt_frames_rejected() {
        let good = sample().encode();
        // truncated header
        assert!(Frame::decode(&good[..10]).is_err());
        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(Frame::decode(&bad).is_err());
        // bad version
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(Frame::decode(&bad).is_err());
        // frame kind out of range
        let mut bad = good.clone();
        bad[5] = 7;
        assert!(Frame::decode(&bad).is_err());
        // plane phase out of range
        let mut bad = good.clone();
        bad[6] = 7;
        assert!(Frame::decode(&bad).is_err());
        // plane axis out of range
        let mut bad = good.clone();
        bad[9] = 3;
        assert!(Frame::decode(&bad).is_err());
        // payload length mismatch
        let mut bad = good.clone();
        bad.pop();
        assert!(Frame::decode(&bad).is_err());
        // declared count larger than payload
        let mut bad = good.clone();
        bad[22] = bad[22].wrapping_add(1);
        assert!(Frame::decode(&bad).is_err());
        // command with trailing garbage
        let mut bad = Frame::Command(Command::Shutdown).encode();
        bad.push(0);
        assert!(Frame::decode(&bad).is_err());
        // command op out of range
        let mut bad = Frame::Command(Command::Shutdown).encode();
        bad[6] = 9;
        assert!(Frame::decode(&bad).is_err());
        // truncated report
        let bad = Frame::Report(ReportMsg {
            src: 0,
            interior_sites: 0,
            steps: 0,
            compute_s: 0.0,
            wait_s: 0.0,
            idle_s: 0.0,
            bytes_sent: 0,
            msgs_sent: 0,
            bytes_axis: [0; 3],
            msgs_axis: [0; 3],
            super_steps: 0,
            bytes_intra: 0,
            bytes_inter: 0,
            msgs_intra: 0,
            msgs_inter: 0,
        })
        .encode();
        assert!(Frame::decode(&bad[..bad.len() - 1]).is_err());
        // a non-plane frame is rejected by the plane-specific decoder
        assert!(PlaneMsg::decode(
            &Frame::Command(Command::Observables).encode()
        )
        .is_err());
    }

    fn sample_trace() -> TraceMsg {
        TraceMsg {
            src: 1,
            spans: vec![
                Span {
                    phase: TracePhase::WaitRecv,
                    step: 3,
                    axis: 1,
                    side: 0,
                    tid: 0,
                    t_start: 0.25,
                    t_end: 1.0 / 3.0,
                },
                Span {
                    phase: TracePhase::Collide,
                    step: 3,
                    axis: AXIS_NONE,
                    side: SIDE_NONE,
                    tid: 4,
                    t_start: -0.0,
                    t_end: f64::MIN_POSITIVE,
                },
                Span {
                    phase: TracePhase::Idle,
                    step: u64::MAX,
                    axis: 2,
                    side: 1,
                    tid: u32::MAX,
                    t_start: 1e-300,
                    t_end: f64::MAX,
                },
            ],
        }
    }

    #[test]
    fn trace_frame_round_trips_bitwise() {
        let t = sample_trace();
        let bytes = Frame::Trace(t.clone()).encode();
        assert_eq!(bytes.len(), TraceMsg::frame_len(t.spans.len()));
        match Frame::decode(&bytes).unwrap() {
            Frame::Trace(back) => {
                assert_eq!(back.src, t.src);
                assert_eq!(back.spans.len(), t.spans.len());
                for (a, b) in back.spans.iter().zip(&t.spans) {
                    assert_eq!(a.phase, b.phase);
                    assert_eq!(a.step, b.step);
                    assert_eq!(a.axis, b.axis);
                    assert_eq!(a.side, b.side);
                    assert_eq!(a.tid, b.tid);
                    assert_eq!(a.t_start.to_bits(), b.t_start.to_bits(),
                               "bitwise f64 timestamps");
                    assert_eq!(a.t_end.to_bits(), b.t_end.to_bits());
                }
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn empty_trace_round_trips() {
        let t = TraceMsg { src: 7, spans: vec![] };
        let bytes = Frame::Trace(t.clone()).encode();
        assert_eq!(bytes.len(), TRACE_HEADER_LEN);
        assert_eq!(Frame::decode(&bytes).unwrap(), Frame::Trace(t));
    }

    #[test]
    fn corrupt_trace_frames_rejected() {
        let good = Frame::Trace(sample_trace()).encode();
        // phase discriminant out of range (first record starts at 14)
        let mut bad = good.clone();
        bad[14] = 12;
        assert!(Frame::decode(&bad).is_err());
        // axis neither 0..3 nor the none marker
        let mut bad = good.clone();
        bad[15] = 3;
        assert!(Frame::decode(&bad).is_err());
        // side neither 0/1 nor the none marker
        let mut bad = good.clone();
        bad[16] = 2;
        assert!(Frame::decode(&bad).is_err());
        // truncated record tail
        let mut bad = good.clone();
        bad.pop();
        assert!(Frame::decode(&bad).is_err());
        // declared count larger than the payload
        let mut bad = good.clone();
        bad[10] = bad[10].wrapping_add(1);
        assert!(Frame::decode(&bad).is_err());
        // truncated header
        assert!(Frame::decode(&good[..12]).is_err());
    }
}
