//! TCP socket transport: the [`Transport`] contract over real sockets.
//!
//! [`SocketTransport`] is the inter-process/inter-host implementation of
//! the byte-level transport the rank world runs on. It moves the
//! **exact** [`crate::comms::wire::Frame`] bytes the in-process
//! [`crate::comms::transport::ChannelTransport`] ships through channels —
//! the wire format is reused verbatim — so the whole session protocol
//! (halo planes, commands, partial reductions, interior gathers, rank
//! reports) carries over to a run spanning OS processes and hosts with no
//! change above this layer.
//!
//! # Stream framing
//!
//! TCP is a byte stream, so each frame is **length-prefixed**:
//!
//! ```text
//! offset  size  field
//!      0     4  frame length `n` (u32 little-endian, <= MAX_FRAME_LEN)
//!      4     n  encoded wire::Frame bytes (self-describing, strict
//!               decode one layer up)
//! ```
//!
//! One TCP connection exists per endpoint pair that talks (rank ↔ rank
//! neighbours plus controller ↔ every rank), established by the
//! rendezvous handshake in [`crate::comms::launcher`], and is used in
//! **both** directions. TCP's in-order delivery per connection gives
//! exactly the per-sender-pair ordering the [`Transport`] contract asks
//! for; ordering across different senders is unspecified, as in MPI.
//!
//! # Receive path and the no-partial-frame guarantee
//!
//! Each connection gets a reader thread that blocks on the socket,
//! reassembles complete frames (handling short reads — a frame may arrive
//! split across many TCP segments), and enqueues them on the endpoint's
//! single inbox. [`Transport::recv_bytes`] /
//! [`Transport::recv_bytes_timeout`] pop that queue, so a receive returns
//! **only whole frames, never a partial one**: a timeout leaves a
//! half-arrived frame with the reader thread, and a stream that dies
//! mid-frame surfaces as an error, not as truncated bytes. A connection
//! that closes cleanly *between* frames is a normal peer exit; when every
//! connection is gone a blocked receive reports the dead world instead of
//! hanging (mirroring `ChannelTransport`'s disconnect semantics). One
//! exception: on a **rank** endpoint the *controller* link closing
//! without a `Shutdown` frame means the driver is gone, and surfaces as
//! an error immediately — a rank process parked at the command barrier
//! still holds open links to its (equally parked) peers, so waiting for
//! a full disconnect would orphan every rank process on every host.
//!
//! # Shutdown
//!
//! Dropping the transport shuts down every connection (both directions)
//! and joins the reader threads. Bytes already written — e.g. the final
//! `Report` frame a rank sends before exiting — are flushed by the OS
//! before the FIN, so the deterministic session teardown (`Shutdown`
//! frame → rank drains → `Report` → close) loses nothing.

use std::io::Read;
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::comms::transport::Transport;
use crate::error::{Error, Result};

/// Upper bound on one frame's encoded size (1 GiB). A length prefix above
/// this is treated as stream corruption rather than honoured with a
/// gigantic allocation.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// What a reader thread hands the inbox: one complete frame, or the
/// reason its connection died mid-frame.
type InboxItem = std::result::Result<Vec<u8>, String>;

/// [`Transport`] over per-peer TCP connections.
///
/// Built by the rendezvous in [`crate::comms::launcher`] (never
/// directly): ranks get one connection per peer they talk to plus one to
/// the session controller; the controller gets one per rank. See the
/// module docs for framing and ordering guarantees.
pub struct SocketTransport {
    rank: usize,
    nranks: usize,
    /// Write sides, indexed by endpoint id (`nranks` = controller). The
    /// slot for this endpoint is `None` — self-sends go through
    /// `self_tx` and only exist in a 1-rank world.
    peers: Vec<Option<TcpStream>>,
    /// Complete frames from every reader thread, in per-connection order.
    inbox: Receiver<InboxItem>,
    /// Loopback sender for the single-rank periodic seam (the lattice's
    /// one rank exchanges halos with itself). `None` in every other
    /// configuration so a dead world disconnects the inbox.
    self_tx: Option<Sender<InboxItem>>,
    readers: Vec<JoinHandle<()>>,
}

impl SocketTransport {
    /// Assemble an endpoint from established, handshaken connections:
    /// `(endpoint id, stream)` pairs, one per peer this endpoint talks
    /// to. `rank == nranks` builds the controller endpoint.
    pub(crate) fn assemble(rank: usize, nranks: usize,
                           conns: Vec<(usize, TcpStream)>)
                           -> Result<SocketTransport> {
        let (tx, inbox) = channel::<InboxItem>();
        let mut peers: Vec<Option<TcpStream>> =
            (0..nranks + 1).map(|_| None).collect();
        let mut readers = Vec::with_capacity(conns.len());
        for (peer, stream) in conns {
            if peer > nranks || peer == rank {
                return Err(Error::Invalid(format!(
                    "comms socket: endpoint {rank} given a connection to \
                     invalid peer {peer} (nranks {nranks})"
                )));
            }
            if peers[peer].is_some() {
                return Err(Error::Invalid(format!(
                    "comms socket: endpoint {rank} given two connections \
                     to peer {peer}"
                )));
            }
            // handshake may have set timeouts; the steady-state reader
            // blocks indefinitely (liveness timeouts live one layer up,
            // in Transport::recv_bytes_timeout)
            stream.set_read_timeout(None)?;
            stream.set_write_timeout(None)?;
            // halo planes are latency-sensitive and sent as one buffered
            // write each — don't let Nagle hold them back
            stream.set_nodelay(true)?;
            peers[peer] = Some(stream.try_clone()?);
            let tx = tx.clone();
            // A clean close from a *peer rank* is normal teardown (it
            // already delivered everything; per-connection order makes
            // its last frames arrive first), but for a rank endpoint the
            // *controller* link closing cleanly without a Shutdown frame
            // means the driver is gone — without this, a rank process
            // parked at the command barrier would keep its peer links
            // open (every peer is parked too), the inbox would never
            // disconnect, and the orphaned process would wait forever.
            let on_eof = (rank < nranks && peer == nranks).then(|| {
                "comms socket: the session controller closed the \
                 connection without Shutdown — driver gone"
                    .to_string()
            });
            readers.push(std::thread::spawn(move || {
                reader_loop(stream, &tx, on_eof)
            }));
        }
        // mirror ChannelTransport: only the single rank of a 1-rank world
        // keeps a handle to its own inbox (the periodic self-seam)
        let self_tx = (nranks == 1 && rank == 0).then(|| tx.clone());
        drop(tx);
        Ok(SocketTransport { rank, nranks, peers, inbox, self_tx, readers })
    }
}

/// Read frames off one connection until it closes, pushing each complete
/// frame to the shared inbox. A clean close at a frame boundary ends the
/// thread silently — unless `on_eof` carries a message (the controller
/// link of a rank endpoint), in which case the close itself is reported;
/// a death mid-frame (or an over-cap length prefix) always forwards the
/// error so the blocked receiver can diagnose it.
fn reader_loop(mut stream: TcpStream, tx: &Sender<InboxItem>,
               on_eof: Option<String>) {
    loop {
        match read_frame(&mut stream) {
            Ok(Some(bytes)) => {
                if tx.send(Ok(bytes)).is_err() {
                    return; // transport dropped; stop reading
                }
            }
            Ok(None) => {
                if let Some(msg) = on_eof {
                    let _ = tx.send(Err(msg));
                }
                return;
            }
            Err(e) => {
                let _ = tx.send(Err(format!(
                    "comms socket: connection died mid-frame: {e}"
                )));
                return;
            }
        }
    }
}

/// Read one length-prefixed frame. `Ok(None)` = the stream closed cleanly
/// at a frame boundary; an EOF anywhere inside a frame is an error — a
/// partial frame is never returned.
fn read_frame(stream: &mut TcpStream) -> std::io::Result<Option<Vec<u8>>> {
    use std::io::{Error as IoError, ErrorKind};
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let n = stream.read(&mut len[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            return Err(IoError::new(
                ErrorKind::UnexpectedEof,
                "stream ended inside a frame length prefix",
            ));
        }
        got += n;
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME_LEN {
        return Err(IoError::new(
            ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME_LEN} cap"),
        ));
    }
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    Ok(Some(buf))
}

impl Transport for SocketTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    /// Only the 1-rank periodic self-seam stays in-process; every real
    /// peer link is a socket — including co-hosted loopback ones, which
    /// still pay the full frame/syscall cost.
    fn peer_is_intra(&self, peer: usize) -> bool {
        peer == self.rank
    }

    fn send_bytes(&mut self, dst: usize, frame: Vec<u8>) -> Result<()> {
        use std::io::Write;
        if frame.len() > MAX_FRAME_LEN {
            return Err(Error::Invalid(format!(
                "comms socket: frame of {} bytes exceeds the \
                 {MAX_FRAME_LEN} cap",
                frame.len()
            )));
        }
        if dst == self.rank {
            // the single rank of a 1-rank world talks to itself across
            // the periodic seam without touching a socket
            let tx = self.self_tx.as_ref().ok_or_else(|| {
                Error::Invalid(format!(
                    "comms: send to endpoint {dst} of a {}-rank world \
                     (self-sends only exist in a 1-rank world)",
                    self.nranks
                ))
            })?;
            return tx.send(Ok(frame)).map_err(|_| {
                Error::Invalid("comms socket: self inbox closed".into())
            });
        }
        let stream = self
            .peers
            .get_mut(dst)
            .and_then(Option::as_mut)
            .ok_or_else(|| {
                Error::Invalid(format!(
                    "comms: send to endpoint {dst} of a {}-rank world \
                     (no connection)",
                    self.nranks
                ))
            })?;
        // one buffered write per frame: with TCP_NODELAY set, prefix and
        // payload leave as a single segment instead of two packets
        let mut msg = Vec::with_capacity(4 + frame.len());
        msg.extend_from_slice(&(frame.len() as u32).to_le_bytes());
        msg.extend_from_slice(&frame);
        stream.write_all(&msg).map_err(|e| {
            Error::Invalid(format!("comms: endpoint {dst} hung up ({e})"))
        })
    }

    /// Coalesce a batch of frames into **one** `write_all`: with
    /// TCP_NODELAY set, the whole super-step ghost-block batch (the f and
    /// g [`crate::comms::wire::PlaneBlockMsg`]s for one neighbour) leaves
    /// as a single buffered write instead of one syscall — and likely one
    /// packet — per frame. Each frame keeps its own length prefix, so the
    /// receiver still sees distinct whole frames in order; the no-partial-
    /// frame guarantee is untouched because the reader thread reassembles
    /// from the byte stream regardless of how the writes were grouped.
    fn send_bytes_batch(&mut self, dst: usize, frames: Vec<Vec<u8>>)
                        -> Result<()> {
        use std::io::Write;
        for frame in &frames {
            if frame.len() > MAX_FRAME_LEN {
                return Err(Error::Invalid(format!(
                    "comms socket: frame of {} bytes exceeds the \
                     {MAX_FRAME_LEN} cap",
                    frame.len()
                )));
            }
        }
        if dst == self.rank {
            // the 1-rank self-seam has no syscall to amortize; deliver
            // each frame individually, exactly like send_bytes
            let tx = self.self_tx.as_ref().ok_or_else(|| {
                Error::Invalid(format!(
                    "comms: send to endpoint {dst} of a {}-rank world \
                     (self-sends only exist in a 1-rank world)",
                    self.nranks
                ))
            })?;
            for frame in frames {
                tx.send(Ok(frame)).map_err(|_| {
                    Error::Invalid(
                        "comms socket: self inbox closed".into(),
                    )
                })?;
            }
            return Ok(());
        }
        let stream = self
            .peers
            .get_mut(dst)
            .and_then(Option::as_mut)
            .ok_or_else(|| {
                Error::Invalid(format!(
                    "comms: send to endpoint {dst} of a {}-rank world \
                     (no connection)",
                    self.nranks
                ))
            })?;
        let total: usize = frames.iter().map(|f| 4 + f.len()).sum();
        let mut msg = Vec::with_capacity(total);
        for frame in &frames {
            msg.extend_from_slice(&(frame.len() as u32).to_le_bytes());
            msg.extend_from_slice(frame);
        }
        stream.write_all(&msg).map_err(|e| {
            Error::Invalid(format!("comms: endpoint {dst} hung up ({e})"))
        })
    }

    fn recv_bytes(&mut self) -> Result<Vec<u8>> {
        match self.inbox.recv() {
            Ok(Ok(bytes)) => Ok(bytes),
            Ok(Err(msg)) => Err(Error::Invalid(msg)),
            Err(_) => Err(Error::Invalid(
                "comms: all peers hung up while receiving".to_string(),
            )),
        }
    }

    fn recv_bytes_timeout(&mut self, timeout: Duration)
                          -> Result<Option<Vec<u8>>> {
        match self.inbox.recv_timeout(timeout) {
            Ok(Ok(bytes)) => Ok(Some(bytes)),
            Ok(Err(msg)) => Err(Error::Invalid(msg)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(Error::Invalid(
                "comms: all peers hung up while receiving".to_string(),
            )),
        }
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        // closing both directions unblocks our reader threads (their
        // reads return EOF/error on the shared underlying socket) and
        // tells every peer we are gone; already-written bytes are still
        // flushed before the FIN
        for s in self.peers.iter().flatten() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;

    /// A raw socket pair on loopback (accepted, connected).
    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let connect = std::thread::spawn(move || {
            TcpStream::connect(addr).unwrap()
        });
        let (accepted, _) = listener.accept().unwrap();
        (accepted, connect.join().unwrap())
    }

    #[test]
    fn frames_cross_a_socket_pair_in_order() {
        let (a, b) = pair();
        let mut t0 = SocketTransport::assemble(0, 2, vec![(1, a)]).unwrap();
        let mut t1 = SocketTransport::assemble(1, 2, vec![(0, b)]).unwrap();
        t0.send_bytes(1, vec![1, 2, 3]).unwrap();
        t0.send_bytes(1, vec![]).unwrap();
        t0.send_bytes(1, vec![9; 100_000]).unwrap();
        assert_eq!(t1.recv_bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(t1.recv_bytes().unwrap(), Vec::<u8>::new());
        assert_eq!(t1.recv_bytes().unwrap(), vec![9; 100_000]);
        // and the reverse direction of the same connection
        t1.send_bytes(0, vec![7]).unwrap();
        assert_eq!(t0.recv_bytes().unwrap(), vec![7]);
    }

    #[test]
    fn batched_frames_arrive_distinct_and_ordered() {
        // one write_all on the sender side, but the receiver still pops
        // each frame whole, in order — the batch is a syscall
        // optimisation, not a wire-format change
        let (a, b) = pair();
        let mut t0 = SocketTransport::assemble(0, 2, vec![(1, a)]).unwrap();
        let mut t1 = SocketTransport::assemble(1, 2, vec![(0, b)]).unwrap();
        t0.send_bytes_batch(1, vec![vec![1, 2], vec![], vec![3; 50_000]])
            .unwrap();
        t0.send_bytes(1, vec![4]).unwrap();
        assert_eq!(t1.recv_bytes().unwrap(), vec![1, 2]);
        assert_eq!(t1.recv_bytes().unwrap(), Vec::<u8>::new());
        assert_eq!(t1.recv_bytes().unwrap(), vec![3; 50_000]);
        assert_eq!(t1.recv_bytes().unwrap(), vec![4]);
        // the 1-rank self-seam takes the per-frame path
        let mut solo = SocketTransport::assemble(0, 1, vec![]).unwrap();
        solo.send_bytes_batch(0, vec![vec![7], vec![8, 9]]).unwrap();
        assert_eq!(solo.recv_bytes().unwrap(), vec![7]);
        assert_eq!(solo.recv_bytes().unwrap(), vec![8, 9]);
    }

    #[test]
    fn timeout_returns_none_without_consuming_anything() {
        let (a, b) = pair();
        let mut t0 = SocketTransport::assemble(0, 2, vec![(1, a)]).unwrap();
        let mut t1 = SocketTransport::assemble(1, 2, vec![(0, b)]).unwrap();
        assert!(t1
            .recv_bytes_timeout(Duration::from_millis(20))
            .unwrap()
            .is_none());
        t0.send_bytes(1, vec![5, 6]).unwrap();
        assert_eq!(t1
            .recv_bytes_timeout(Duration::from_secs(10))
            .unwrap(),
            Some(vec![5, 6]));
    }

    #[test]
    fn truncated_frame_is_an_error_not_a_partial_delivery() {
        let (a, mut raw) = pair();
        let mut t = SocketTransport::assemble(0, 2, vec![(1, a)]).unwrap();
        // a length prefix promising 16 bytes, then only 8, then FIN
        raw.write_all(&16u32.to_le_bytes()).unwrap();
        raw.write_all(&[0u8; 8]).unwrap();
        drop(raw);
        let got = t.recv_bytes_timeout(Duration::from_secs(10));
        assert!(got.is_err(), "partial frame must error, got {got:?}");
    }

    #[test]
    fn oversize_length_prefix_rejected() {
        let (a, mut raw) = pair();
        let mut t = SocketTransport::assemble(0, 2, vec![(1, a)]).unwrap();
        raw.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let got = t.recv_bytes_timeout(Duration::from_secs(10));
        assert!(got.is_err(), "over-cap length must error, got {got:?}");
    }

    #[test]
    fn clean_close_surfaces_as_disconnect() {
        let (a, b) = pair();
        let mut t0 = SocketTransport::assemble(0, 2, vec![(1, a)]).unwrap();
        let t1 = SocketTransport::assemble(1, 2, vec![(0, b)]).unwrap();
        drop(t1); // peer exits between frames
        assert!(t0.recv_bytes().is_err());
        assert!(t0.recv_bytes_timeout(Duration::from_secs(30)).is_err());
    }

    #[test]
    fn controller_eof_surfaces_to_a_rank_endpoint() {
        // a rank endpoint whose controller link (peer id = nranks) dies
        // cleanly without a Shutdown frame must see an error — not wait
        // at the command barrier forever while its peer links stay open
        let (a, raw) = pair();
        let mut t = SocketTransport::assemble(0, 2, vec![(2, a)]).unwrap();
        drop(raw); // the driver vanishes
        let got = t.recv_bytes_timeout(Duration::from_secs(10));
        assert!(got.is_err(), "controller EOF must error, got {got:?}");
    }

    #[test]
    fn one_rank_world_self_sends_across_the_seam() {
        // no sockets at all: the single rank's periodic seam is a local
        // loopback, exactly like ChannelTransport::mesh(1)
        let mut t = SocketTransport::assemble(0, 1, vec![]).unwrap();
        t.send_bytes(0, vec![4, 2]).unwrap();
        assert_eq!(t.recv_bytes().unwrap(), vec![4, 2]);
    }

    #[test]
    fn invalid_destinations_rejected() {
        let (a, _b) = pair();
        let mut t = SocketTransport::assemble(0, 2, vec![(1, a)]).unwrap();
        assert!(t.send_bytes(5, vec![1]).is_err(), "out of range");
        assert!(t.send_bytes(0, vec![1]).is_err(),
                "multi-rank worlds never self-send");
        // assembling with a self-connection or duplicate peer is refused
        let (c, _d) = pair();
        assert!(SocketTransport::assemble(0, 2, vec![(0, c)]).is_err());
        let (e, _f) = pair();
        let (g, _h) = pair();
        assert!(SocketTransport::assemble(0, 2, vec![(1, e), (1, g)])
            .is_err());
    }
}
