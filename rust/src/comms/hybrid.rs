//! Hybrid transport: per-link routing between in-process channels and
//! host-to-host sockets.
//!
//! [`HybridTransport`] is the paper's MPI-combined deployment shape
//! (threads inside a node, messages only across nodes) applied to the
//! rank world: one OS process per **host** runs all of that host's
//! ranks as resident threads, and every peer link is routed by where
//! the peer lives —
//!
//! * **co-hosted peer** → an in-process `std::sync::mpsc` channel: the
//!   encoded frame bytes are handed over directly, with no
//!   length-prefix framing, no syscall, and no extra copy;
//! * **remote peer** → a TCP stream to that peer's host process,
//!   shared by every (local rank, remote rank) pair between the two
//!   hosts — plus one stream per host to the driver for the control
//!   plane.
//!
//! Because grid ranks are numbered z-fastest and the rendezvous places
//! each host's ranks on consecutive ids
//! ([`crate::comms::launcher::host_grouped_order`]), the co-hosted
//! links are exactly the *inner-axis* grid faces — the highest-traffic
//! ones — so a hybrid world moves most halo bytes over channels and
//! only the outer-axis cut over the network.
//!
//! # Envelope framing on host links
//!
//! A wire frame carries its source but not its destination, and one
//! stream now serves several (sender, receiver) pairs, so each frame
//! on a host link travels in a small **envelope**:
//!
//! ```text
//! offset  size  field
//!      0     4  destination endpoint id (u32 little-endian)
//!      4     4  frame length `n` (u32 little-endian, <= MAX_FRAME_LEN)
//!      8     n  encoded wire::Frame bytes
//! ```
//!
//! One reader thread per host link reassembles envelopes and routes
//! each complete frame to the destination endpoint's inbox; writes go
//! through one mutex-guarded writer per link, each frame (or
//! [`Transport::send_bytes_batch`] batch) leaving as a single
//! `write_all`. That preserves both transport guarantees across the
//! merged path: no receive ever returns a partial frame (the reader
//! owns reassembly), and per-sender-pair order holds because a
//! sender's envelopes are written whole, in order, onto one TCP stream
//! that delivers in order — and the reader enqueues in stream order.
//! Channel links inherit both guarantees from `mpsc` directly.
//!
//! # Failure semantics
//!
//! Each link closing carries a per-link EOF policy, mirroring
//! [`crate::comms::socket::SocketTransport`]: a host-pair link closing
//! cleanly is normal teardown (silent); the *driver* link closing
//! without a `Shutdown` frame means the driver is gone and surfaces as
//! an error to every resident rank; and on the **driver's** side a
//! host link that closes before every resident rank's `Report` frame
//! crossed it means the host process died mid-run — also an error, so
//! a lost host is diagnosed instead of waited on. A link dying
//! mid-envelope is always an error, never truncated bytes.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::comms::socket::MAX_FRAME_LEN;
use crate::comms::transport::Transport;
use crate::comms::wire::is_report_frame;
use crate::error::{Error, Result};

/// Fixed size of one host-link envelope header.
const ENVELOPE_LEN: usize = 8;

/// What a link reader hands an inbox: one complete frame, or the
/// reason the link died.
type InboxItem = std::result::Result<Vec<u8>, String>;

/// What a clean close of one host link means to the endpoints behind
/// it.
pub(crate) enum EofPolicy {
    /// Normal teardown (host-pair links: the remote host finished its
    /// shutdown and exited).
    Silent,
    /// Always an error (a rank's driver link: the driver never closes
    /// before `Shutdown`, so a clean EOF means the driver is gone).
    Always(String),
    /// An error unless `expect` rank `Report` frames crossed the link
    /// first (the driver's side of a host link: reports are the last
    /// frames a rank sends, so a close with all of them delivered is a
    /// normal host-process exit and anything earlier is a mid-run host
    /// death).
    UnlessReports { expect: usize, msg: String },
}

/// One established, handshaken host link: a stream plus the remote
/// endpoint ids it serves and what its clean close means.
pub(crate) struct HostLink {
    pub stream: TcpStream,
    /// Remote endpoint ids reachable over this stream (a remote host's
    /// rank block, or `[nranks]` for the driver).
    pub peers: Vec<usize>,
    pub eof: EofPolicy,
}

/// Mutex-guarded write side of one host link, shared by every local
/// endpoint that routes over it. Each envelope (or batch of envelopes)
/// leaves as one `write_all` under the lock, so concurrent rank
/// threads never interleave partial frames.
struct LinkWriter {
    stream: Mutex<TcpStream>,
}

impl LinkWriter {
    fn write_checked(&self, dst: usize, msg: &[u8]) -> Result<()> {
        let mut stream = self.stream.lock().map_err(|_| {
            Error::Invalid(
                "comms hybrid: a sender panicked holding the link writer"
                    .to_string(),
            )
        })?;
        stream.write_all(msg).map_err(|e| {
            Error::Invalid(format!("comms: endpoint {dst} hung up ({e})"))
        })
    }

    /// One frame, one buffered write (with TCP_NODELAY the envelope
    /// and payload leave as a single segment).
    fn send(&self, dst: usize, frame: &[u8]) -> Result<()> {
        let mut msg = Vec::with_capacity(ENVELOPE_LEN + frame.len());
        msg.extend_from_slice(&(dst as u32).to_le_bytes());
        msg.extend_from_slice(&(frame.len() as u32).to_le_bytes());
        msg.extend_from_slice(frame);
        self.write_checked(dst, &msg)
    }

    /// A whole batch as **one** `write_all` — the super-step ghost
    /// blocks keep their single-syscall coalescing on the socket side
    /// of a hybrid world. Each frame keeps its own envelope, so the
    /// receiver still sees distinct whole frames in order.
    fn send_batch(&self, dst: usize, frames: &[Vec<u8>]) -> Result<()> {
        let total: usize =
            frames.iter().map(|f| ENVELOPE_LEN + f.len()).sum();
        let mut msg = Vec::with_capacity(total);
        for frame in frames {
            msg.extend_from_slice(&(dst as u32).to_le_bytes());
            msg.extend_from_slice(&(frame.len() as u32).to_le_bytes());
            msg.extend_from_slice(frame);
        }
        self.write_checked(dst, &msg)
    }
}

/// The per-process spine of a hybrid mesh: owns the link streams and
/// reader threads on behalf of every resident endpoint. The last
/// endpoint dropped drops this, which closes every link (both
/// directions, unblocking the readers; already-written bytes — the
/// final `Report` frames — are flushed before the FIN) and joins the
/// readers.
struct MeshCore {
    streams: Vec<TcpStream>,
    readers: Mutex<Vec<JoinHandle<()>>>,
}

impl Drop for MeshCore {
    fn drop(&mut self) {
        for s in &self.streams {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Ok(mut readers) = self.readers.lock() {
            for h in readers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// [`Transport`] with per-peer routing: channels to co-hosted
/// endpoints, shared TCP links to remote hosts.
///
/// Built by [`assemble`] (via the rendezvous in
/// [`crate::comms::launcher`], never directly): a host process gets
/// one endpoint per resident rank, all sharing the host's link
/// streams; the driver gets the lone controller endpoint. See the
/// module docs for framing, ordering, and failure semantics.
pub struct HybridTransport {
    rank: usize,
    nranks: usize,
    /// Senders into co-hosted endpoints' inboxes, indexed by endpoint
    /// id (`nranks` = controller). `None` for this endpoint itself and
    /// for every remote endpoint.
    chan: Vec<Option<Sender<InboxItem>>>,
    /// Write sides of the host links, indexed by endpoint id — every
    /// co-hosted endpoint shares the same `Arc` per link.
    links: Vec<Option<Arc<LinkWriter>>>,
    /// Complete frames from co-hosted senders and link readers, in
    /// per-sender order.
    inbox: Receiver<InboxItem>,
    /// Loopback sender for the single-rank periodic seam; `None` in
    /// every other configuration so a dead world disconnects the
    /// inbox.
    self_tx: Option<Sender<InboxItem>>,
    /// Keeps the link streams and readers alive until the last
    /// resident endpoint is gone.
    _core: Arc<MeshCore>,
}

/// Build every resident endpoint of one hybrid process: `locals` are
/// the endpoint ids living here (a host's rank block, or `[nranks]`
/// for the driver), `links` the handshaken streams to every other
/// host process and/or the driver. Every endpoint id `0..=nranks`
/// must be covered exactly once, by `locals` or by one link.
pub(crate) fn assemble(nranks: usize, locals: &[usize],
                       links: Vec<HostLink>)
                       -> Result<Vec<HybridTransport>> {
    let endpoints = nranks + 1;
    if locals.is_empty() {
        return Err(Error::Invalid(
            "comms hybrid: a process with no resident endpoints".into(),
        ));
    }
    // every endpoint id is either resident or behind exactly one link
    let mut owner: Vec<Option<&'static str>> = vec![None; endpoints];
    let claim = |owner: &mut Vec<Option<&'static str>>, id: usize,
                 what: &'static str|
     -> Result<()> {
        if id >= endpoints {
            return Err(Error::Invalid(format!(
                "comms hybrid: endpoint {id} out of range for a \
                 {nranks}-rank world"
            )));
        }
        if let Some(prev) = owner[id] {
            return Err(Error::Invalid(format!(
                "comms hybrid: endpoint {id} claimed twice ({prev} and \
                 {what})"
            )));
        }
        owner[id] = Some(what);
        Ok(())
    };
    for &id in locals {
        claim(&mut owner, id, "local")?;
    }
    for link in &links {
        for &id in &link.peers {
            claim(&mut owner, id, "a host link")?;
        }
    }
    if let Some(id) = owner.iter().position(Option::is_none) {
        return Err(Error::Invalid(format!(
            "comms hybrid: endpoint {id} is neither resident nor behind \
             any host link"
        )));
    }

    // one inbox per resident endpoint
    let mut txs: Vec<Option<Sender<InboxItem>>> = vec![None; endpoints];
    let mut rxs: Vec<Option<Receiver<InboxItem>>> = Vec::new();
    rxs.resize_with(endpoints, || None);
    for &id in locals {
        let (tx, rx) = channel::<InboxItem>();
        txs[id] = Some(tx);
        rxs[id] = Some(rx);
    }

    // wire the links: a shared writer per link plus one reader thread
    // routing inbound envelopes to the resident inboxes
    let mut writers: Vec<Option<Arc<LinkWriter>>> = vec![None; endpoints];
    let mut streams = Vec::with_capacity(links.len());
    let mut readers = Vec::with_capacity(links.len());
    for link in links {
        let HostLink { stream, peers, eof } = link;
        // handshake may have set timeouts; steady state blocks (liveness
        // timeouts live up in Transport::recv_bytes_timeout) and halo
        // frames are latency-sensitive single writes — no Nagle
        stream.set_read_timeout(None)?;
        stream.set_write_timeout(None)?;
        stream.set_nodelay(true)?;
        let writer = Arc::new(LinkWriter {
            stream: Mutex::new(stream.try_clone()?),
        });
        for &id in &peers {
            writers[id] = Some(Arc::clone(&writer));
        }
        let routes: Vec<Option<Sender<InboxItem>>> = txs.clone();
        let reader_stream = stream.try_clone()?;
        streams.push(stream);
        readers.push(std::thread::spawn(move || {
            link_reader(reader_stream, routes, eof)
        }));
    }
    let core = Arc::new(MeshCore {
        streams,
        readers: Mutex::new(readers),
    });

    // endpoints: channel senders to co-hosted peers, shared link
    // writers to everyone else
    let out = locals
        .iter()
        .map(|&me| {
            let chan: Vec<Option<Sender<InboxItem>>> = txs
                .iter()
                .enumerate()
                .map(|(id, tx)| {
                    (id != me).then(|| tx.clone()).flatten()
                })
                .collect();
            // mirror the other transports: only the single rank of a
            // 1-rank world keeps a handle to its own inbox (the
            // periodic self-seam)
            let self_tx = (nranks == 1 && me == 0)
                .then(|| txs[me].clone())
                .flatten();
            HybridTransport {
                rank: me,
                nranks,
                chan,
                links: writers.clone(),
                inbox: rxs[me].take().expect("one endpoint per local id"),
                self_tx,
                _core: Arc::clone(&core),
            }
        })
        .collect();
    Ok(out)
}

/// Read envelopes off one host link until it closes, routing each
/// complete frame to the destination endpoint's inbox. A frame for an
/// endpoint that already exited is dropped (normal teardown skew: its
/// co-hosted siblings may still be draining); a frame for an endpoint
/// that was never resident here, a death mid-envelope, or a clean
/// close the link's [`EofPolicy`] forbids is broadcast as an error to
/// every resident inbox.
fn link_reader(mut stream: TcpStream,
               routes: Vec<Option<Sender<InboxItem>>>, eof: EofPolicy) {
    let broadcast = |msg: String| {
        for tx in routes.iter().flatten() {
            let _ = tx.send(Err(msg.clone()));
        }
    };
    let mut reports = 0usize;
    loop {
        match read_envelope(&mut stream) {
            Ok(Some((dst, frame))) => {
                if is_report_frame(&frame) {
                    reports += 1;
                }
                match routes.get(dst).and_then(Option::as_ref) {
                    Some(tx) => {
                        // a send failure means that endpoint exited;
                        // keep serving its co-hosted siblings
                        let _ = tx.send(Ok(frame));
                    }
                    None => {
                        broadcast(format!(
                            "comms hybrid: a host link routed a frame to \
                             endpoint {dst}, which is not resident here"
                        ));
                        return;
                    }
                }
            }
            Ok(None) => {
                match eof {
                    EofPolicy::Silent => {}
                    EofPolicy::Always(msg) => broadcast(msg),
                    EofPolicy::UnlessReports { expect, msg } => {
                        if reports < expect {
                            broadcast(msg);
                        }
                    }
                }
                return;
            }
            Err(e) => {
                broadcast(format!(
                    "comms hybrid: a host link died mid-frame: {e}"
                ));
                return;
            }
        }
    }
}

/// Read one enveloped frame. `Ok(None)` = the stream closed cleanly at
/// an envelope boundary; an EOF anywhere inside an envelope is an
/// error — a partial frame is never surfaced.
fn read_envelope(stream: &mut TcpStream)
                 -> std::io::Result<Option<(usize, Vec<u8>)>> {
    use std::io::{Error as IoError, ErrorKind};
    let mut head = [0u8; ENVELOPE_LEN];
    let mut got = 0;
    while got < ENVELOPE_LEN {
        let n = stream.read(&mut head[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            return Err(IoError::new(
                ErrorKind::UnexpectedEof,
                "stream ended inside an envelope header",
            ));
        }
        got += n;
    }
    let dst = u32::from_le_bytes(head[..4].try_into().unwrap()) as usize;
    let len = u32::from_le_bytes(head[4..].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN {
        return Err(IoError::new(
            ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME_LEN} cap"),
        ));
    }
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    Ok(Some((dst, buf)))
}

impl HybridTransport {
    fn no_link(&self, dst: usize) -> Error {
        Error::Invalid(format!(
            "comms: send to endpoint {dst} of a {}-rank world (no link)",
            self.nranks
        ))
    }

    fn check_len(&self, len: usize) -> Result<()> {
        if len > MAX_FRAME_LEN {
            return Err(Error::Invalid(format!(
                "comms hybrid: frame of {len} bytes exceeds the \
                 {MAX_FRAME_LEN} cap"
            )));
        }
        Ok(())
    }

    fn send_self(&self, frame: Vec<u8>) -> Result<()> {
        let tx = self.self_tx.as_ref().ok_or_else(|| {
            Error::Invalid(format!(
                "comms: send to endpoint {} of a {}-rank world \
                 (self-sends only exist in a 1-rank world)",
                self.rank, self.nranks
            ))
        })?;
        tx.send(Ok(frame)).map_err(|_| {
            Error::Invalid("comms hybrid: self inbox closed".into())
        })
    }
}

impl Transport for HybridTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    /// Channel links (co-hosted peers and the 1-rank self-seam) are
    /// intra-host; host links are not.
    fn peer_is_intra(&self, peer: usize) -> bool {
        peer == self.rank
            || self.chan.get(peer).map_or(false, Option::is_some)
    }

    fn send_bytes(&mut self, dst: usize, frame: Vec<u8>) -> Result<()> {
        if dst == self.rank {
            return self.send_self(frame);
        }
        if let Some(tx) = self.chan.get(dst).and_then(Option::as_ref) {
            // co-hosted: hand the encoded bytes over, no framing, no
            // syscall
            return tx.send(Ok(frame)).map_err(|_| {
                Error::Invalid(format!("comms: endpoint {dst} hung up"))
            });
        }
        if let Some(writer) = self.links.get(dst).and_then(Option::as_ref)
        {
            self.check_len(frame.len())?;
            return writer.send(dst, &frame);
        }
        Err(self.no_link(dst))
    }

    /// Batches keep the per-link split: a socket link coalesces the
    /// whole batch into one `write_all` (the super-step lever), a
    /// channel link hands each frame over individually — there is no
    /// syscall to amortize, and frames stay distinct either way.
    fn send_bytes_batch(&mut self, dst: usize, frames: Vec<Vec<u8>>)
                        -> Result<()> {
        if dst == self.rank {
            for frame in frames {
                self.send_self(frame)?;
            }
            return Ok(());
        }
        if let Some(tx) = self.chan.get(dst).and_then(Option::as_ref) {
            for frame in frames {
                tx.send(Ok(frame)).map_err(|_| {
                    Error::Invalid(format!(
                        "comms: endpoint {dst} hung up"
                    ))
                })?;
            }
            return Ok(());
        }
        if let Some(writer) = self.links.get(dst).and_then(Option::as_ref)
        {
            for frame in &frames {
                self.check_len(frame.len())?;
            }
            return writer.send_batch(dst, &frames);
        }
        Err(self.no_link(dst))
    }

    fn recv_bytes(&mut self) -> Result<Vec<u8>> {
        match self.inbox.recv() {
            Ok(Ok(bytes)) => Ok(bytes),
            Ok(Err(msg)) => Err(Error::Invalid(msg)),
            Err(_) => Err(Error::Invalid(
                "comms: all peers hung up while receiving".to_string(),
            )),
        }
    }

    fn recv_bytes_timeout(&mut self, timeout: Duration)
                          -> Result<Option<Vec<u8>>> {
        match self.inbox.recv_timeout(timeout) {
            Ok(Ok(bytes)) => Ok(Some(bytes)),
            Ok(Err(msg)) => Err(Error::Invalid(msg)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(Error::Invalid(
                "comms: all peers hung up while receiving".to_string(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::wire::{Frame, ReportMsg};
    use std::net::TcpListener;

    /// A raw socket pair on loopback (accepted, connected).
    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let connect =
            std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (accepted, _) = listener.accept().unwrap();
        (accepted, connect.join().unwrap())
    }

    fn report_frame(src: u32) -> Vec<u8> {
        Frame::Report(ReportMsg {
            src,
            interior_sites: 0,
            steps: 0,
            compute_s: 0.0,
            wait_s: 0.0,
            idle_s: 0.0,
            bytes_sent: 0,
            msgs_sent: 0,
            bytes_axis: [0; 3],
            msgs_axis: [0; 3],
            super_steps: 0,
            bytes_intra: 0,
            bytes_inter: 0,
            msgs_intra: 0,
            msgs_inter: 0,
        })
        .encode()
    }

    /// A 4-rank world on two simulated hosts (ranks 0,1 | 2,3) plus a
    /// driver: the full link shape the launcher's rendezvous builds.
    fn two_host_world() -> (Vec<HybridTransport>, Vec<HybridTransport>,
                            HybridTransport) {
        let (ab_a, ab_b) = pair();
        let (ad_a, ad_d) = pair();
        let (bd_b, bd_d) = pair();
        let driver_gone = || EofPolicy::Always("driver gone".into());
        let host_gone = |expect| EofPolicy::UnlessReports {
            expect,
            msg: "host gone".into(),
        };
        let a = assemble(4, &[0, 1], vec![
            HostLink { stream: ab_a, peers: vec![2, 3],
                       eof: EofPolicy::Silent },
            HostLink { stream: ad_a, peers: vec![4], eof: driver_gone() },
        ])
        .unwrap();
        let b = assemble(4, &[2, 3], vec![
            HostLink { stream: ab_b, peers: vec![0, 1],
                       eof: EofPolicy::Silent },
            HostLink { stream: bd_b, peers: vec![4], eof: driver_gone() },
        ])
        .unwrap();
        let mut d = assemble(4, &[4], vec![
            HostLink { stream: ad_d, peers: vec![0, 1],
                       eof: host_gone(2) },
            HostLink { stream: bd_d, peers: vec![2, 3],
                       eof: host_gone(2) },
        ])
        .unwrap();
        (a, b, d.pop().unwrap())
    }

    #[test]
    fn routes_channel_and_socket_links_both_ways() {
        let (mut a, mut b, mut ctl) = two_host_world();
        // co-hosted: rank 0 -> rank 1 over a channel
        a[0].send_bytes(1, vec![1, 2]).unwrap();
        assert_eq!(a[1].recv_bytes().unwrap(), vec![1, 2]);
        // cross-host: rank 0 -> rank 2 and rank 3 share one stream
        a[0].send_bytes(2, vec![3]).unwrap();
        a[0].send_bytes(3, vec![4]).unwrap();
        assert_eq!(b[0].recv_bytes().unwrap(), vec![3]);
        assert_eq!(b[1].recv_bytes().unwrap(), vec![4]);
        // and back
        b[1].send_bytes(0, vec![5]).unwrap();
        assert_eq!(a[0].recv_bytes().unwrap(), vec![5]);
        // control plane both ways over the driver links
        ctl.send_bytes(1, vec![6]).unwrap();
        assert_eq!(a[1].recv_bytes().unwrap(), vec![6]);
        b[0].send_bytes(4, vec![7]).unwrap();
        assert_eq!(ctl.recv_bytes().unwrap(), vec![7]);
    }

    #[test]
    fn per_sender_order_holds_across_the_merged_inbox() {
        let (mut a, mut b, _ctl) = two_host_world();
        // rank 2 hears from rank 3 (channel) and rank 0 (socket); each
        // sender's own sequence must arrive in order
        for i in 0..50u8 {
            a[0].send_bytes(2, vec![0, i]).unwrap();
            b[1].send_bytes(2, vec![1, i]).unwrap();
        }
        let mut next = [0u8; 2];
        for _ in 0..100 {
            let got = b[0].recv_bytes().unwrap();
            let sender = got[0] as usize;
            assert_eq!(got[1], next[sender], "per-sender order");
            next[sender] += 1;
        }
        assert_eq!(next, [50, 50]);
    }

    #[test]
    fn peer_is_intra_reflects_link_kind() {
        let (a, b, ctl) = two_host_world();
        assert!(a[0].peer_is_intra(1), "co-hosted peer");
        assert!(a[0].peer_is_intra(0), "self");
        assert!(!a[0].peer_is_intra(2), "cross-host peer");
        assert!(!a[0].peer_is_intra(4), "driver link");
        assert!(b[1].peer_is_intra(2));
        assert!(!ctl.peer_is_intra(0), "every rank is remote to the \
                                        driver");
    }

    #[test]
    fn batched_frames_arrive_distinct_and_ordered_on_both_link_kinds() {
        let (mut a, mut b, _ctl) = two_host_world();
        // socket link: one write_all, distinct frames on arrival
        a[0].send_bytes_batch(3, vec![vec![1], vec![], vec![2; 50_000]])
            .unwrap();
        a[0].send_bytes(3, vec![9]).unwrap();
        assert_eq!(b[1].recv_bytes().unwrap(), vec![1]);
        assert_eq!(b[1].recv_bytes().unwrap(), Vec::<u8>::new());
        assert_eq!(b[1].recv_bytes().unwrap(), vec![2; 50_000]);
        assert_eq!(b[1].recv_bytes().unwrap(), vec![9]);
        // channel link: frames hand over individually, still in order
        a[1].send_bytes_batch(0, vec![vec![4], vec![5, 6]]).unwrap();
        assert_eq!(a[0].recv_bytes().unwrap(), vec![4]);
        assert_eq!(a[0].recv_bytes().unwrap(), vec![5, 6]);
    }

    #[test]
    fn timeout_returns_none_without_consuming_anything() {
        let (mut a, mut b, _ctl) = two_host_world();
        assert!(b[0]
            .recv_bytes_timeout(Duration::from_millis(20))
            .unwrap()
            .is_none());
        a[0].send_bytes(2, vec![8]).unwrap();
        assert_eq!(
            b[0].recv_bytes_timeout(Duration::from_secs(10)).unwrap(),
            Some(vec![8])
        );
    }

    #[test]
    fn invalid_destinations_rejected() {
        let (mut a, _b, _ctl) = two_host_world();
        assert!(a[0].send_bytes(9, vec![1]).is_err(), "out of range");
        assert!(a[0].send_bytes(0, vec![1]).is_err(),
                "multi-rank worlds never self-send");
    }

    #[test]
    fn misrouted_envelope_surfaces_as_an_error() {
        // a link whose remote claims to serve rank 1 but addresses a
        // frame to endpoint 3, which lives nowhere near this process
        let (ours, mut raw) = pair();
        let mut eps = assemble(3, &[0, 2], vec![HostLink {
            stream: ours,
            peers: vec![1, 3],
            eof: EofPolicy::Silent,
        }])
        .unwrap();
        let mut msg = Vec::new();
        msg.extend_from_slice(&3u32.to_le_bytes());
        msg.extend_from_slice(&1u32.to_le_bytes());
        msg.push(42);
        raw.write_all(&msg).unwrap();
        let got = eps[0].recv_bytes_timeout(Duration::from_secs(10));
        assert!(got.is_err(), "misroute must error, got {got:?}");
    }

    #[test]
    fn truncated_envelope_is_an_error_not_a_partial_delivery() {
        let (ours, mut raw) = pair();
        let mut eps = assemble(1, &[0], vec![HostLink {
            stream: ours,
            peers: vec![1],
            eof: EofPolicy::Silent,
        }])
        .unwrap();
        // an envelope promising 16 bytes, then only 4, then FIN
        let mut msg = Vec::new();
        msg.extend_from_slice(&0u32.to_le_bytes());
        msg.extend_from_slice(&16u32.to_le_bytes());
        msg.extend_from_slice(&[0u8; 4]);
        raw.write_all(&msg).unwrap();
        drop(raw);
        let got = eps[0].recv_bytes_timeout(Duration::from_secs(10));
        assert!(got.is_err(), "partial frame must error, got {got:?}");
    }

    #[test]
    fn driver_link_eof_surfaces_to_resident_ranks() {
        let (ours, raw) = pair();
        let mut eps = assemble(2, &[0, 1], vec![HostLink {
            stream: ours,
            peers: vec![2],
            eof: EofPolicy::Always("driver gone".into()),
        }])
        .unwrap();
        drop(raw); // the driver vanishes
        for ep in &mut eps {
            let got = ep.recv_bytes_timeout(Duration::from_secs(10));
            assert!(got.is_err(),
                    "driver EOF must error on every rank, got {got:?}");
        }
    }

    #[test]
    fn host_death_before_reports_errors_but_clean_exit_is_silent() {
        // mid-run death: the host closes with no reports delivered
        let (ours, raw) = pair();
        let mut ctl = assemble(2, &[2], vec![HostLink {
            stream: ours,
            peers: vec![0, 1],
            eof: EofPolicy::UnlessReports {
                expect: 2,
                msg: "host gone".into(),
            },
        }])
        .unwrap();
        drop(raw);
        let got = ctl[0].recv_bytes_timeout(Duration::from_secs(10));
        assert!(got.is_err(), "host death must error, got {got:?}");

        // normal teardown: both reports cross the link, then EOF —
        // silent, like a socket rank link closing after its report
        let (ours, raw) = pair();
        let mut ctl = assemble(2, &[2], vec![HostLink {
            stream: ours,
            peers: vec![0, 1],
            eof: EofPolicy::UnlessReports {
                expect: 2,
                msg: "host gone".into(),
            },
        }])
        .unwrap();
        {
            let mut sender = assemble(2, &[0, 1], vec![HostLink {
                stream: raw,
                peers: vec![2],
                eof: EofPolicy::Silent,
            }])
            .unwrap();
            sender[0].send_bytes(2, report_frame(0)).unwrap();
            sender[1].send_bytes(2, report_frame(1)).unwrap();
        } // host process exits cleanly
        assert!(is_report_frame(&ctl[0].recv_bytes().unwrap()));
        assert!(is_report_frame(&ctl[0].recv_bytes().unwrap()));
        assert!(ctl[0]
            .recv_bytes_timeout(Duration::from_millis(100))
            .unwrap()
            .is_none(),
            "clean post-report exit stays silent");
    }

    #[test]
    fn trace_frames_do_not_satisfy_the_report_count() {
        use crate::comms::wire::TraceMsg;
        // a tracing host ships its span batch just before its Report —
        // if it dies *between* the two, the EOF accounting must still
        // flag the missing report: only true Report frames count, a
        // Trace must never make the death look like a clean exit
        let (ours, raw) = pair();
        let mut ctl = assemble(1, &[1], vec![HostLink {
            stream: ours,
            peers: vec![0],
            eof: EofPolicy::UnlessReports {
                expect: 1,
                msg: "host gone".into(),
            },
        }])
        .unwrap();
        {
            let mut sender = assemble(1, &[0], vec![HostLink {
                stream: raw,
                peers: vec![1],
                eof: EofPolicy::Silent,
            }])
            .unwrap();
            sender[0]
                .send_bytes(1,
                            Frame::Trace(TraceMsg { src: 0, spans: vec![] })
                                .encode())
                .unwrap();
        } // the host process dies before its Report crosses the link
        let first = ctl[0].recv_bytes().unwrap();
        assert!(!is_report_frame(&first),
                "the trace batch itself arrives, and is not a report");
        let got = ctl[0].recv_bytes_timeout(Duration::from_secs(10));
        assert!(got.is_err(),
                "death between Trace and Report must error, got {got:?}");
    }

    #[test]
    fn one_rank_world_self_sends_across_the_seam() {
        let (ours, _raw) = pair();
        let mut eps = assemble(1, &[0], vec![HostLink {
            stream: ours,
            peers: vec![1],
            eof: EofPolicy::Silent,
        }])
        .unwrap();
        eps[0].send_bytes(0, vec![4, 2]).unwrap();
        assert_eq!(eps[0].recv_bytes().unwrap(), vec![4, 2]);
        eps[0].send_bytes_batch(0, vec![vec![7], vec![8]]).unwrap();
        assert_eq!(eps[0].recv_bytes().unwrap(), vec![7]);
        assert_eq!(eps[0].recv_bytes().unwrap(), vec![8]);
    }

    #[test]
    fn dead_world_disconnects_instead_of_hanging() {
        let (mut a, b, ctl) = two_host_world();
        let mut r0 = a.remove(0);
        drop(a); // co-hosted sibling gone
        drop(b); // remote host gone (its MeshCore closes the A–B link)
        drop(ctl); // driver gone — but its link EOF carries a message
        let got = r0.recv_bytes_timeout(Duration::from_secs(10));
        assert!(got.is_err(), "dead world must error, got {got:?}");
    }

    #[test]
    fn assemble_validates_coverage() {
        // endpoint claimed twice (local + link)
        let (s, _k) = pair();
        assert!(assemble(2, &[0, 1], vec![HostLink {
            stream: s,
            peers: vec![1, 2],
            eof: EofPolicy::Silent,
        }])
        .is_err());
        // endpoint out of range
        let (s, _k) = pair();
        assert!(assemble(2, &[0, 1], vec![HostLink {
            stream: s,
            peers: vec![7],
            eof: EofPolicy::Silent,
        }])
        .is_err());
        // uncovered endpoint (nobody serves the controller id 2)
        assert!(assemble(2, &[0, 1], vec![]).is_err());
        // no resident endpoints at all
        let (s, _k) = pair();
        assert!(assemble(2, &[], vec![HostLink {
            stream: s,
            peers: vec![0, 1, 2],
            eof: EofPolicy::Silent,
        }])
        .is_err());
    }
}
