//! `artifacts/manifest.json` — the contract between the AOT compile path
//! and the Rust runtime: shapes, lattice, VVL block and the constant
//! values baked into each executable.

use std::path::Path;

use crate::error::{Error, Result};
use crate::free_energy::symmetric::FeParams;
use crate::util::json::Json;

/// Shape/dtype of one executable input or output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn from_json(v: &Json) -> Result<IoSpec> {
        let shape = v
            .get("shape")
            .as_array()?
            .iter()
            .map(Json::as_usize)
            .collect::<Result<Vec<_>>>()?;
        Ok(IoSpec { shape, dtype: v.get("dtype").as_str()?.to_string() })
    }
}

/// One AOT artifact as described by the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// collision | full_step | multi_step | gradient | scale
    pub kind: String,
    pub lattice: Option<String>,
    pub vvl_block: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub n_sites: Option<usize>,
    pub nvel: Option<usize>,
    pub grid: Option<Vec<usize>>,
    /// Timesteps fused into one launch (multi_step artifacts).
    pub steps: Option<u64>,
    /// Free-energy constants baked into the executable at AOT time.
    pub params: Option<FeParams>,
    /// Scale factor baked into `scale` artifacts.
    pub a: Option<f64>,
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<ArtifactMeta> {
        let io = |key: &str| -> Result<Vec<IoSpec>> {
            v.get(key)
                .as_array()?
                .iter()
                .map(IoSpec::from_json)
                .collect()
        };
        let opt_usize = |key: &str| -> Result<Option<usize>> {
            let f = v.get(key);
            if f.is_null() { Ok(None) } else { Ok(Some(f.as_usize()?)) }
        };
        let params = {
            let p = v.get("params");
            if p.is_null() {
                None
            } else {
                Some(FeParams {
                    a: p.get("a").as_f64()?,
                    b: p.get("b").as_f64()?,
                    kappa: p.get("kappa").as_f64()?,
                    gamma: p.get("gamma").as_f64()?,
                    tau_f: p.get("tau_f").as_f64()?,
                    tau_g: p.get("tau_g").as_f64()?,
                })
            }
        };
        Ok(ArtifactMeta {
            name: v.get("name").as_str()?.to_string(),
            file: v.get("file").as_str()?.to_string(),
            kind: v.get("kind").as_str()?.to_string(),
            lattice: if v.get("lattice").is_null() {
                None
            } else {
                Some(v.get("lattice").as_str()?.to_string())
            },
            vvl_block: v.get("vvl_block").as_usize().unwrap_or(0),
            inputs: io("inputs")?,
            outputs: io("outputs")?,
            n_sites: opt_usize("n_sites")?,
            nvel: opt_usize("nvel")?,
            grid: if v.get("grid").is_null() {
                None
            } else {
                Some(
                    v.get("grid")
                        .as_array()?
                        .iter()
                        .map(Json::as_usize)
                        .collect::<Result<Vec<_>>>()?,
                )
            },
            steps: opt_usize("steps")?.map(|s| s as u64),
            params,
            a: if v.get("a").is_null() {
                None
            } else {
                Some(v.get("a").as_f64()?)
            },
        })
    }

    /// Whether this artifact serves `(kind, lattice, grid)`.
    pub fn matches_grid(&self, kind: &str, lattice: &str,
                        grid: &[usize]) -> bool {
        self.kind == kind
            && self.lattice.as_deref() == Some(lattice)
            && self.grid.as_deref() == Some(grid)
    }

    /// Whether this artifact serves a flat-`n` kernel `(kind, lattice, n)`.
    pub fn matches_flat(&self, kind: &str, lattice: &str, n: usize) -> bool {
        self.kind == kind
            && self.lattice.as_deref() == Some(lattice)
            && self.n_sites == Some(n)
    }
}

/// Load and parse the manifest in `dir`.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        Error::Parse(format!(
            "cannot read {}: {e}; run `make artifacts`",
            path.display()
        ))
    })?;
    Json::parse(&text)?
        .as_array()?
        .iter()
        .map(ArtifactMeta::from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"[
      {"name": "collision_d3q19_n4096_vvl256", "file": "c.hlo.txt",
       "kind": "collision", "lattice": "d3q19", "vvl_block": 256,
       "inputs": [{"shape": [19, 4096], "dtype": "f64"},
                  {"shape": [19, 4096], "dtype": "f64"},
                  {"shape": [3, 4096], "dtype": "f64"},
                  {"shape": [4096], "dtype": "f64"}],
       "outputs": [{"shape": [19, 4096], "dtype": "f64"},
                   {"shape": [19, 4096], "dtype": "f64"}],
       "n_sites": 4096, "nvel": 19,
       "params": {"a": -0.0625, "b": 0.0625, "kappa": 0.04,
                  "gamma": 1.0, "tau_f": 1.0, "tau_g": 0.8}},
      {"name": "gradient_16x16x16", "file": "g.hlo.txt",
       "kind": "gradient", "lattice": null, "vvl_block": 0,
       "inputs": [{"shape": [16, 16, 16], "dtype": "f64"}],
       "outputs": [{"shape": [3, 16, 16, 16], "dtype": "f64"},
                   {"shape": [16, 16, 16], "dtype": "f64"}],
       "grid": [16, 16, 16], "n_sites": 4096}
    ]"#;

    fn parse_sample() -> Vec<ArtifactMeta> {
        Json::parse(SAMPLE)
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(ArtifactMeta::from_json)
            .map(|r| r.unwrap())
            .collect()
    }

    #[test]
    fn parses_sample() {
        let metas = parse_sample();
        assert_eq!(metas.len(), 2);
        let c = &metas[0];
        assert!(c.matches_flat("collision", "d3q19", 4096));
        assert!(!c.matches_flat("collision", "d2q9", 4096));
        assert_eq!(c.inputs[0].len(), 19 * 4096);
        assert_eq!(c.params.unwrap().tau_g, 0.8);
        let g = &metas[1];
        assert!(g.lattice.is_none());
        assert_eq!(g.grid.as_deref(), Some(&[16, 16, 16][..]));
        assert!(g.matches_grid("gradient", "x", &[16, 16, 16]) == false);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if dir.join("manifest.json").exists() {
            let metas = load_manifest(&dir).unwrap();
            assert!(!metas.is_empty());
            assert!(metas.iter().any(|m| m.kind == "collision"));
            // every entry's file exists
            for m in &metas {
                assert!(dir.join(&m.file).exists(), "{} missing", m.file);
            }
        }
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = load_manifest(std::path::Path::new("/nonexistent"))
            .unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
