//! PJRT execution engine: HLO-text artifacts -> compiled executables ->
//! f64 in / f64 out, with an executable cache so each artifact is compiled
//! exactly once per process (the paper's "one compiled executable per
//! model variant").

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

// The pure-std build stands the PJRT bindings in with a stub whose client
// construction fails (XLA tests then skip). Swap this alias for the real
// `xla` crate to enable the accelerator path — see runtime/pjrt_stub.rs.
use super::manifest::{self, ArtifactMeta};
use super::pjrt_stub as xla;

/// Owns the PJRT client, the manifest and the compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    metas: Vec<ArtifactMeta>,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load the manifest in `dir` and connect the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let metas = manifest::load_manifest(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, metas, compiled: HashMap::new() })
    }

    /// Default artifact directory: `$TARGETDP_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("TARGETDP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    /// First artifact satisfying `pred`.
    pub fn find(&self, pred: impl Fn(&ArtifactMeta) -> bool)
                -> Option<&ArtifactMeta> {
        self.metas.iter().find(|m| pred(m))
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .metas
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| Error::Invalid(format!("unknown artifact {name}")))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Invalid(format!("non-utf8 path {path:?}"))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with flat f64 inputs (shapes from the
    /// manifest) and return the flat f64 outputs (tuple decomposed).
    pub fn execute(&mut self, name: &str, inputs: &[&[f64]])
                   -> Result<Vec<Vec<f64>>> {
        self.ensure_compiled(name)?;
        let meta = self
            .metas
            .iter()
            .find(|m| m.name == name)
            .expect("checked by ensure_compiled");

        if inputs.len() != meta.inputs.len() {
            return Err(Error::Invalid(format!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&meta.inputs) {
            if data.len() != spec.len() {
                return Err(Error::Invalid(format!(
                    "{name}: input size {} != manifest {:?}",
                    data.len(),
                    spec.shape
                )));
            }
            let dims: Vec<i64> =
                spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }

        let exe = self.compiled.get(name).expect("compiled above");
        let result = exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose and flatten
        let parts = tuple.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            return Err(Error::Xla(format!(
                "{name}: executable returned {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            )));
        }
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            out.push(part.to_vec::<f64>()?);
        }
        Ok(out)
    }

    /// Number of compiled executables held in the cache.
    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("artifacts", &self.metas.len())
            .field("compiled", &self.compiled.len())
            .finish()
    }
}
