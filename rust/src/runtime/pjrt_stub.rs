//! Build-time stub for the `xla` (PJRT bindings) crate.
//!
//! The accelerator path of this repo executes AOT-lowered HLO through the
//! PJRT C API via the `xla` Rust bindings. Those bindings need a compiled
//! XLA runtime and are not part of the default **pure-std** build, so this
//! module mirrors the exact API surface [`super::engine::Runtime`] uses
//! and fails at *client construction* with an actionable error — every
//! XLA-dependent test detects that failure and skips, exactly as it does
//! on a machine without artifacts.
//!
//! To enable the real PJRT path, add the bindings crate and swap the
//! `use super::pjrt_stub as xla;` alias in `runtime/engine.rs` (and the
//! matching alias in `error.rs`) for the real crate; no other code
//! changes.

use std::fmt;

/// Error type standing in for `xla::Error`.
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PjrtStubError({})", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT bindings not linked: this is the pure-std build; the xla \
         backend requires the `xla` bindings crate (see \
         runtime/pjrt_stub.rs)"
            .into(),
    ))
}

/// Stand-in for `xla::PjRtClient`; construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T])
                      -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_actionably() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("pure-std build"), "{err}");
    }
}
