//! The AOT runtime: loads `artifacts/*.hlo.txt` (lowered once by
//! `python/compile/aot.py`) and executes them on the PJRT CPU client.
//! Python is never on this path — the Rust binary is self-contained once
//! the artifacts exist.

pub mod engine;
pub mod manifest;
pub mod pjrt_stub;

pub use engine::Runtime;
pub use manifest::{ArtifactMeta, IoSpec};
