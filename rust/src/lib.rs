//! # targetDP — lattice data parallelism with portable performance
//!
//! Reproduction of Gray & Stratford, *"targetDP: an Abstraction of Lattice
//! Based Parallelism with Portable Performance"* (HPCC 2014) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The paper's C-preprocessor framework maps lattice-site parallelism onto
//! **TLP** (thread-level) and **ILP** (instruction-level, via a tunable
//! *virtual vector length*, VVL) for either SIMD multi-core CPUs or GPUs,
//! behind a host/target memory model. Here:
//!
//! * [`targetdp`] — the programming layer itself: host/target memory
//!   management (`targetMalloc`, `copyToTarget`, masked copies, constant
//!   tables), the TLP chunk scheduler, VVL strip-mined ILP kernels, and the
//!   [`targetdp::Target`] trait with three backends: *host-scalar* (the
//!   paper's original-code analog), *host-SIMD* (VVL strip-mining for the
//!   auto-vectorizer) and *XLA* (the accelerator analog: AOT-compiled
//!   JAX/Pallas kernels executed through PJRT).
//! * [`lattice`] — structured-grid substrate: geometry, SoA lattice fields,
//!   halo masks, domain decomposition, VTK/CSV output.
//! * [`comms`] — the distribution level above targetDP (the paper's
//!   "combined with MPI" tier): concurrent slab ranks over pluggable
//!   transports with halo exchange overlapped against interior compute.
//! * [`obs`] — observability: the per-thread phase span recorder behind
//!   `--trace-out`/`--report-json` (Chrome-trace timelines and JSON run
//!   reports for decomposed runs; off by default and free when off).
//! * [`lb`] — the motivating application: a binary-fluid lattice-Boltzmann
//!   engine (D2Q9/D3Q19) whose *binary collision* kernel is the paper's
//!   Figure-1 benchmark.
//! * [`free_energy`] — symmetric (phi^4) free-energy sector: chemical
//!   potential, thermodynamic pressure tensor, finite-difference gradients.
//! * [`baseline`] — the "original Ludwig" comparator: AoS layout, model-
//!   extent (19/3) innermost loops, compiler-found ILP.
//! * [`runtime`] — PJRT client wrapper that loads `artifacts/*.hlo.txt`
//!   (AOT-lowered by `python/compile/aot.py`) and executes them; Python is
//!   never on the request path.
//! * [`coordinator`] — configuration, the timestep pipeline, metrics.
//!
//! See `DESIGN.md` for the paper-to-system map and `EXPERIMENTS.md` for the
//! reproduced results.

pub mod baseline;
pub mod bench;
pub mod comms;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod free_energy;
pub mod lattice;
pub mod lb;
pub mod obs;
pub mod runtime;
pub mod targetdp;
pub mod util;

pub use error::{Error, Result};
