//! targetDP CLI: run simulations, serve socket ranks, inspect
//! artifacts/targets.
//!
//! ```text
//! targetdp run --config examples/spinodal.toml
//! targetdp run --backend xla --lattice d3q19 --size 16 --steps 100
//! targetdp run --ranks 4 --transport socket          # 4 OS processes
//! targetdp run --ranks 4 --transport hybrid          # 1 process/host
//! targetdp rank --connect host:7777                  # one remote rank
//! targetdp rank --connect host:7777 --local-ranks 4  # one remote host
//! targetdp info
//! ```

use std::process::ExitCode;

use targetdp::config::{Config, FaultCfg, OutputCfg, SimulationCfg,
                       TargetCfg};
use targetdp::coordinator::{run_rank_process, run_simulation};
use targetdp::runtime::Runtime;
use targetdp::util::cli::Args;

const USAGE: &str = "\
targetdp — lattice-based data parallelism with portable performance
(reproduction of Gray & Stratford, HPCC 2014)

USAGE:
    targetdp run [--config FILE] [--backend B] [--lattice L] [--size N]
                 [--steps K] [--vvl V] [--threads T] [--multi-step M]
                 [--ranks R] [--grid PX,PY,PZ]
                 [--overlap true|false] [--comms-depth K]
                 [--pin-threads true|false]
                 [--observables reduced|gather]
                 [--transport channel|socket|hybrid]
                 [--rank-server HOST:PORT]
                 [--out DIR] [--vtk] [--trace-out FILE]
                 [--report-json FILE] [--heartbeat SECS]
                 [--checkpoint-every BLOCKS] [--checkpoint-out FILE]
                 [--restore FILE] [--max-restarts N]
                 [--kill-rank R --kill-step S [--kill-point P]]
    targetdp rank --connect HOST:PORT [--rank R] [--local-ranks N]
    targetdp info
    targetdp help

run options (ignored when --config is given):
    --backend     host-simd | host-scalar | xla     [host-simd]
    --lattice     d3q19 | d2q9                      [d3q19]
    --size        cubic extent (d2q9: size^2 x 1)   [16]
    --steps       timesteps                         [100]
    --vvl         virtual vector length             [8]
    --threads     TLP threads (0 = autodetect)      [1]
    --multi-step  host blocked steps/launch, 0=auto [0]
    --ranks       concurrent comms ranks            [1]
    --grid        rank grid PX,PY,PZ (product =
                  ranks; 3D Cartesian decomposition
                  with face exchange), \"\" = auto
                  minimal-surface factorisation     [auto]
    --overlap     overlap halo exchange w/ compute  [true]
    --comms-depth steps per halo exchange (super-
                  steps; ranks > 1), 0 = auto       [1]
    --pin-threads pin rank TLP workers to cores
                  (Linux sched_setaffinity)         [false]
    --observables per-block reduction for ranks > 1:
                  distributed partials (reduced) or
                  full-state gather                 [reduced]
    --transport   channel (rank threads), socket
                  (one OS process per rank) or
                  hybrid (one OS process per host;
                  channel links inside, sockets
                  between hosts)                    [channel]
    --rank-server socket/hybrid mode: listen on
                  HOST:PORT for manually started
                  ranks (one `targetdp rank
                  --connect` per rank, or per host
                  with --local-ranks N in hybrid
                  mode) instead of spawning them
                  locally                           [spawn-local]
    --out         output directory for CSV/VTK      [none]
    --vtk         dump a phi snapshot at the end
    --trace-out   write a Chrome trace_event JSON
                  span timeline (ranks > 1; open in
                  chrome://tracing or Perfetto)     [off]
    --report-json write a JSON run report: config
                  echo + per-rank counters + phase
                  histogram (ranks > 1)             [off]
    --heartbeat   driver progress line at most every
                  N seconds between logging blocks
                  (step/total, mlups, max wait%)    [0 = off]
    --checkpoint-every
                  write a TDPK checkpoint every N
                  logging blocks (ranks > 1;
                  decomposition-independent, restore
                  anywhere)                         [0 = off]
    --checkpoint-out
                  checkpoint file path              [<out>/checkpoint.tdpk]
    --restore     resume from this checkpoint file
                  instead of the initial condition  [off]
    --max-restarts
                  supervised recovery: on a world
                  error relaunch from the last
                  checkpoint up to N times          [0 = off]
    --backoff-ms  sleep N*attempt ms before each
                  supervised relaunch               [100]
    --retry-ranks relaunch with this many ranks
                  (elastic recovery; 0 = same)      [0]
    --wait-timeout
                  rank receive timeout in seconds
                  (dead-neighbour detection bound)  [0 = 120]
    --kill-rank   fault injection: rank to kill     [0]
    --kill-step   step at which the fault fires     [0 = off]
    --kill-point  step | mid | barrier              [step]
    --kill-repeat keep the fault armed across
                  supervised restarts               [false]

rank options (a rank/host process; normally spawned by the driver):
    --connect     the driver's rank-server address  (required)
    --rank        request a specific rank id (the
                  block's first id with
                  --local-ranks > 1)                [driver assigns]
    --local-ranks ranks this process carries as
                  resident threads (hybrid driver)  [1]
";

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> targetdp::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.command.as_str() {
        "run" => {
            let cfg = match args.get("config") {
                Some(path) => Config::from_file(std::path::Path::new(path))?,
                None => {
                    let lattice = args.str_or("lattice", "d3q19");
                    let size = args.usize_or("size", 16)?;
                    let lz = if lattice == "d2q9" { 1 } else { size };
                    Config {
                        simulation: SimulationCfg {
                            lattice,
                            lx: size,
                            ly: size,
                            lz,
                            steps: args.u64_or("steps", 100)?,
                            init: args.str_or("init", "spinodal"),
                            noise: 0.05,
                            seed: 1234,
                            radius: size as f64 / 4.0,
                        },
                        target: TargetCfg {
                            backend: args.str_or("backend", "host-simd"),
                            vvl: args.usize_or("vvl", 8)?,
                            threads: args.usize_or("threads", 1)?,
                            multi_step: args.u64_or("multi-step", 0)?,
                            ranks: args.usize_or("ranks", 1)?,
                            grid: args.str_or("grid", ""),
                            overlap: args.bool_or("overlap", true)?,
                            comms_depth: args.u64_or("comms-depth", 1)?,
                            pin_threads: args.bool_or("pin-threads",
                                                      false)?,
                            observables: args.str_or("observables",
                                                     "reduced"),
                            transport: args.str_or("transport", "channel"),
                            rank_server: args.str_or("rank-server", ""),
                            ..Default::default()
                        },
                        free_energy: Default::default(),
                        output: OutputCfg {
                            every: args.u64_or("every", 50)?,
                            dir: args.str_or("out", ""),
                            vtk: args.has("vtk"),
                            trace_out: args.str_or("trace-out", ""),
                            report_json: args.str_or("report-json", ""),
                            heartbeat: args.u64_or("heartbeat", 0)?,
                            checkpoint_every:
                                args.u64_or("checkpoint-every", 0)?,
                            checkpoint_out:
                                args.str_or("checkpoint-out", ""),
                            restore: args.str_or("restore", ""),
                        },
                        fault: FaultCfg {
                            kill_rank: args.u64_or("kill-rank", 0)?,
                            kill_step: args.u64_or("kill-step", 0)?,
                            kill_point: args.str_or("kill-point", "step"),
                            kill_repeat: args.bool_or("kill-repeat",
                                                      false)?,
                            max_restarts:
                                args.u64_or("max-restarts", 0)?,
                            backoff_ms: args.u64_or("backoff-ms", 100)?,
                            retry_ranks: args.u64_or("retry-ranks", 0)?,
                            wait_timeout_s:
                                args.u64_or("wait-timeout", 0)?,
                        },
                    }
                }
            };
            run_simulation(&cfg)?;
            Ok(())
        }
        "rank" => {
            let server = args
                .get("connect")
                .ok_or_else(|| {
                    targetdp::Error::Invalid(
                        "rank needs --connect HOST:PORT (the driver's \
                         rank-server address)"
                            .into(),
                    )
                })?
                .to_string();
            let want_rank = match args.get("rank") {
                Some(_) => Some(args.usize_or("rank", 0)?),
                None => None,
            };
            let local_ranks = args.usize_or("local-ranks", 1)?;
            run_rank_process(&server, want_rank, local_ranks)
        }
        "info" => {
            println!("targetDP targets:");
            println!("  host-scalar  per-site loops, compiler-found ILP");
            println!("  host-simd    TLP x ILP (VVL strip-mining)");
            println!("  xla          AOT JAX/Pallas via PJRT");
            match Runtime::load(Runtime::default_dir()) {
                Ok(rt) => {
                    println!("\nPJRT platform: {}", rt.platform());
                    println!("artifacts ({}):", rt.artifacts().len());
                    for m in rt.artifacts() {
                        println!(
                            "  {:<42} kind={:<10} vvl_block={}",
                            m.name, m.kind, m.vvl_block
                        );
                    }
                }
                Err(e) => println!(
                    "\nno artifacts loaded ({e}); run `make artifacts`"
                ),
            }
            Ok(())
        }
        "" | "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(targetdp::Error::Invalid(format!(
            "unknown command {other:?}; try `targetdp help`"
        ))),
    }
}
