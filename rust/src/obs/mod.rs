//! `obs` — observability: phase-level tracing and run telemetry.
//!
//! The comms tier reports three aggregate floats per rank
//! (`compute_s`/`wait_s`/`idle_s`); this module adds the level below:
//! **span timelines**. Every rank (and, optionally, every TLP worker)
//! records `(phase, t_start, t_end, step, axis/side)` intervals into a
//! preallocated ring buffer ([`trace::SpanRecorder`]) against a shared
//! per-rank epoch. Recording is **off by default** and a disabled
//! recorder is a no-op — the hot paths stay bit-identical and pay one
//! branch per instrumentation site.
//!
//! At `Shutdown` a tracing rank ships its buffer to the driver as a
//! `Trace` wire frame ([`crate::comms::wire::TraceMsg`]) just before its
//! lifetime `Report`; the driver merges the per-rank timelines into a
//! Chrome `trace_event` JSON (`--trace-out`, one pid per rank, one tid
//! per TLP worker — open in `chrome://tracing` or Perfetto) and a
//! machine-readable run report (`--report-json`) with per-rank counters
//! and a per-phase time histogram.

pub mod trace;

pub use trace::{PoolTrace, Span, SpanRecorder, TracePhase};
