//! Low-overhead per-thread span recorder.
//!
//! A [`Span`] is one timed interval of a fixed [`TracePhase`] — pack,
//! send, wait, unpack, the compute sweeps, reductions, idle — tagged
//! with the timestep and (for halo traffic) the axis/side it served.
//! Spans accumulate in a preallocated ring buffer ([`SpanRecorder`]);
//! when the ring wraps, the oldest spans are overwritten and counted in
//! [`SpanRecorder::dropped`], so a tracing run can never grow its memory
//! footprint.
//!
//! Timestamps are `f64` seconds since a per-rank **epoch**
//! ([`std::time::Instant`]) shared by the rank thread and its TLP
//! workers — so one rank's spans are mutually ordered. Epochs are *not*
//! synchronized across ranks (socket ranks are separate processes with
//! separate clocks); the Chrome-trace export keeps one timeline (pid)
//! per rank, which is exactly the granularity the epoch guarantees.
//!
//! A recorder built with [`SpanRecorder::disabled`] allocates nothing
//! and turns [`SpanRecorder::record`] into a single branch — the
//! parity-critical paths are instrumented unconditionally and pay only
//! that branch when tracing is off.
//!
//! ```
//! use std::time::Instant;
//! use targetdp::obs::trace::{SpanRecorder, TracePhase, AXIS_NONE,
//!                            SIDE_NONE};
//!
//! let mut rec = SpanRecorder::enabled(64, Instant::now());
//! let t0 = rec.now();
//! // ... the work being timed ...
//! rec.close(TracePhase::Interior, 3, AXIS_NONE, SIDE_NONE, t0);
//! assert_eq!(rec.len(), 1);
//! let spans = rec.take_spans();
//! assert_eq!(spans[0].phase, TracePhase::Interior);
//! assert!(spans[0].t_end >= spans[0].t_start);
//! ```

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// `axis` tag of a span that is not tied to a lattice axis.
pub const AXIS_NONE: u8 = 255;
/// `side` tag of a span that is not tied to a low/high side.
pub const SIDE_NONE: u8 = 255;

/// The fixed phase vocabulary of the instrumented hot paths. The
/// discriminants are the wire encoding (`Trace` frame span records) and
/// are stable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum TracePhase {
    /// Packing halo faces / ghost blocks into send buffers.
    Pack = 0,
    /// Handing a packed message to the transport (`isend`).
    Send = 1,
    /// Blocked in `wait`/`wait_block` for a halo message.
    WaitRecv = 2,
    /// Unpacking a received face / ghost block into the halo.
    Unpack = 3,
    /// Interior compute that needs no halo (phi moments, deep sweeps).
    Interior = 4,
    /// Halo-adjacent compute finished after message arrival (edge
    /// planes, shell runs, trapezoid rims).
    EdgeRim = 5,
    /// Finite-difference gradient/laplacian sweeps.
    Gradient = 6,
    /// Collision (collide, or fused collide→stream) sweeps.
    Collide = 7,
    /// Pure streaming sweeps (the unfused second exchange half).
    Stream = 8,
    /// Observable reductions (mass/momentum/phi partial sums).
    Reduce = 9,
    /// Synchronization that is neither wait-for-halo nor idle (reserved
    /// for collective barriers; currently unused by the slab/grid
    /// schedules).
    Barrier = 10,
    /// Parked at the command barrier between driver blocks.
    Idle = 11,
}

impl TracePhase {
    /// Every phase, in discriminant order.
    pub const ALL: [TracePhase; 12] = [
        TracePhase::Pack,
        TracePhase::Send,
        TracePhase::WaitRecv,
        TracePhase::Unpack,
        TracePhase::Interior,
        TracePhase::EdgeRim,
        TracePhase::Gradient,
        TracePhase::Collide,
        TracePhase::Stream,
        TracePhase::Reduce,
        TracePhase::Barrier,
        TracePhase::Idle,
    ];

    /// Decode a wire discriminant; `None` for anything out of range.
    pub fn from_u8(v: u8) -> Option<TracePhase> {
        TracePhase::ALL.get(v as usize).copied()
    }

    /// Stable lowercase name (the Chrome-trace event name and the
    /// `--report-json` histogram key).
    pub fn name(self) -> &'static str {
        match self {
            TracePhase::Pack => "pack",
            TracePhase::Send => "send",
            TracePhase::WaitRecv => "wait_recv",
            TracePhase::Unpack => "unpack",
            TracePhase::Interior => "interior",
            TracePhase::EdgeRim => "edge_rim",
            TracePhase::Gradient => "gradient",
            TracePhase::Collide => "collide",
            TracePhase::Stream => "stream",
            TracePhase::Reduce => "reduce",
            TracePhase::Barrier => "barrier",
            TracePhase::Idle => "idle",
        }
    }
}

/// One recorded interval: what ran, when, on which timestep, and (for
/// halo traffic) which face it served. `tid` distinguishes the rank
/// thread (0) from its TLP workers (worker index + 1) inside one rank's
/// timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub phase: TracePhase,
    pub step: u64,
    /// 0/1/2 = x/y/z, or [`AXIS_NONE`].
    pub axis: u8,
    /// 0 = low, 1 = high, or [`SIDE_NONE`].
    pub side: u8,
    /// 0 = the rank thread, `w + 1` = TLP worker `w`.
    pub tid: u32,
    /// Seconds since the rank's epoch.
    pub t_start: f64,
    /// Seconds since the rank's epoch (`>= t_start`).
    pub t_end: f64,
}

/// A preallocated ring buffer of [`Span`]s for one thread.
///
/// Disabled (the default everywhere) it allocates nothing and records
/// nothing; enabled it holds at most `capacity` spans, overwriting the
/// oldest on wrap (and counting the overwrites). Recording never
/// allocates after construction.
#[derive(Debug)]
pub struct SpanRecorder {
    enabled: bool,
    epoch: Instant,
    buf: Vec<Span>,
    cap: usize,
    /// Next write slot once the ring is full.
    head: usize,
    /// Spans overwritten after the ring wrapped.
    dropped: u64,
}

impl SpanRecorder {
    /// The no-op recorder: no buffer, `record` is one branch.
    pub fn disabled() -> SpanRecorder {
        SpanRecorder {
            enabled: false,
            epoch: Instant::now(),
            buf: Vec::new(),
            cap: 0,
            head: 0,
            dropped: 0,
        }
    }

    /// A live recorder holding at most `capacity` spans, timestamped
    /// against `epoch`.
    pub fn enabled(capacity: usize, epoch: Instant) -> SpanRecorder {
        let cap = capacity.max(1);
        SpanRecorder {
            enabled: true,
            epoch,
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Seconds since the epoch — `0.0` (without reading the clock) when
    /// disabled, so `let t0 = rec.now(); ...; rec.close(...)` costs two
    /// branches on the parity path.
    #[inline]
    pub fn now(&self) -> f64 {
        if self.enabled {
            self.epoch.elapsed().as_secs_f64()
        } else {
            0.0
        }
    }

    /// Append one span (no-op when disabled; overwrites the oldest span
    /// once `capacity` is reached).
    #[inline]
    pub fn record(&mut self, span: Span) {
        if !self.enabled {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(span);
        } else {
            self.buf[self.head] = span;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Close an interval opened with [`SpanRecorder::now`] on the rank
    /// thread (tid 0): `[t0, now]`.
    #[inline]
    pub fn close(&mut self, phase: TracePhase, step: u64, axis: u8,
                 side: u8, t0: f64) {
        if !self.enabled {
            return;
        }
        let t_end = self.epoch.elapsed().as_secs_f64();
        self.record(Span { phase, step, axis, side, tid: 0, t_start: t0,
                           t_end });
    }

    /// Spans currently held (at most the capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Spans overwritten after the ring wrapped.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Drain the buffer oldest-first, leaving the recorder empty (and
    /// still enabled).
    pub fn take_spans(&mut self) -> Vec<Span> {
        let head = std::mem::take(&mut self.head);
        let buf = std::mem::take(&mut self.buf);
        if self.enabled {
            self.buf = Vec::with_capacity(self.cap);
        }
        if head == 0 {
            return buf; // never wrapped: already oldest-first
        }
        let mut out = Vec::with_capacity(buf.len());
        out.extend_from_slice(&buf[head..]);
        out.extend_from_slice(&buf[..head]);
        out
    }
}

/// Span recording for the TLP worker pool: one ring per worker plus the
/// *context* (phase, step) the rank thread publishes before each traced
/// kernel launch.
///
/// The rank thread owns the kernel schedule but the workers own the
/// time: before launching a traced sweep the rank calls
/// [`PoolTrace::set_context`]; each worker times its own share of the
/// launch and records one span (tid = worker + 1) under that context.
/// Context reads/writes are relaxed atomics — the pool's launch
/// handshake already orders them, and a torn read is impossible (two
/// independent words, each updated before the launch they describe).
#[derive(Debug)]
pub struct PoolTrace {
    epoch: Instant,
    phase: AtomicU8,
    step: AtomicU64,
    recs: Vec<Mutex<SpanRecorder>>,
}

impl PoolTrace {
    /// One ring of `capacity` spans per worker, timestamped against the
    /// rank's `epoch`.
    pub fn new(nworkers: usize, epoch: Instant, capacity: usize)
               -> Arc<PoolTrace> {
        let recs = (0..nworkers.max(1))
            .map(|_| Mutex::new(SpanRecorder::enabled(capacity, epoch)))
            .collect();
        Arc::new(PoolTrace {
            epoch,
            phase: AtomicU8::new(TracePhase::Interior as u8),
            step: AtomicU64::new(0),
            recs,
        })
    }

    /// Publish the phase/step the next traced launch belongs to.
    #[inline]
    pub fn set_context(&self, phase: TracePhase, step: u64) {
        self.phase.store(phase as u8, Ordering::Relaxed);
        self.step.store(step, Ordering::Relaxed);
    }

    /// Seconds since the rank's epoch.
    #[inline]
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record worker `w`'s share of the current launch as `[t0, now]`
    /// under the published context.
    pub fn record(&self, w: usize, t0: f64) {
        let t_end = self.now();
        let phase = TracePhase::from_u8(self.phase.load(Ordering::Relaxed))
            .unwrap_or(TracePhase::Interior);
        let step = self.step.load(Ordering::Relaxed);
        if let Some(rec) = self.recs.get(w) {
            rec.lock().unwrap().record(Span {
                phase,
                step,
                axis: AXIS_NONE,
                side: SIDE_NONE,
                tid: w as u32 + 1,
                t_start: t0,
                t_end,
            });
        }
    }

    /// Drain every worker's ring, worker-major.
    pub fn drain(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for rec in &self.recs {
            out.extend(rec.lock().unwrap().take_spans());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(phase: TracePhase, step: u64, t: f64) -> Span {
        Span { phase, step, axis: AXIS_NONE, side: SIDE_NONE, tid: 0,
               t_start: t, t_end: t + 0.5 }
    }

    #[test]
    fn disabled_recorder_is_a_no_op() {
        let mut rec = SpanRecorder::disabled();
        assert!(!rec.is_enabled());
        assert_eq!(rec.now(), 0.0, "disabled now() never reads the clock");
        rec.record(span(TracePhase::Pack, 1, 0.0));
        rec.close(TracePhase::Interior, 2, AXIS_NONE, SIDE_NONE, 0.0);
        assert!(rec.is_empty());
        assert_eq!(rec.buf.capacity(), 0, "disabled allocates nothing");
        assert!(rec.take_spans().is_empty());
    }

    #[test]
    fn capacity_wrap_keeps_newest_oldest_first() {
        let mut rec = SpanRecorder::enabled(4, Instant::now());
        for i in 0..7u64 {
            rec.record(span(TracePhase::Collide, i, i as f64));
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 3, "three spans overwritten");
        let spans = rec.take_spans();
        let steps: Vec<u64> = spans.iter().map(|s| s.step).collect();
        assert_eq!(steps, vec![3, 4, 5, 6],
                   "the newest capacity spans survive, oldest first");
        // the recorder keeps working after a drain
        assert!(rec.is_empty());
        rec.record(span(TracePhase::Stream, 9, 0.0));
        assert_eq!(rec.take_spans()[0].step, 9);
    }

    #[test]
    fn epoch_timestamps_are_monotonic() {
        let mut rec = SpanRecorder::enabled(16, Instant::now());
        let mut last = 0.0;
        for step in 0..5 {
            let t0 = rec.now();
            assert!(t0 >= last, "now() never goes backwards");
            rec.close(TracePhase::Interior, step, AXIS_NONE, SIDE_NONE,
                      t0);
            last = rec.now();
        }
        let spans = rec.take_spans();
        assert_eq!(spans.len(), 5);
        for w in spans.windows(2) {
            assert!(w[1].t_start >= w[0].t_start,
                    "successive spans move forward in epoch time");
        }
        for s in &spans {
            assert!(s.t_end >= s.t_start);
            assert_eq!(s.tid, 0, "close() records the rank thread");
        }
    }

    #[test]
    fn pool_trace_records_under_published_context() {
        let pt = PoolTrace::new(2, Instant::now(), 8);
        pt.set_context(TracePhase::Gradient, 7);
        let t0 = pt.now();
        pt.record(0, t0);
        pt.record(1, t0);
        pt.set_context(TracePhase::Collide, 8);
        pt.record(1, pt.now());
        let spans = pt.drain();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].phase, TracePhase::Gradient);
        assert_eq!(spans[0].step, 7);
        assert_eq!(spans[0].tid, 1, "worker 0 records tid 1");
        assert_eq!(spans[2].phase, TracePhase::Collide);
        assert_eq!(spans[2].tid, 2);
        assert!(pt.drain().is_empty(), "drain leaves the rings empty");
    }
}
