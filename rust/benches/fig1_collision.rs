//! E1 — **Figure 1** of the paper: runtime of the Ludwig binary-collision
//! benchmark under four implementations.
//!
//! Paper bars -> this testbed (DESIGN.md section 2):
//!
//! | paper                          | here                                 |
//! |--------------------------------|--------------------------------------|
//! | CPU original (+OpenMP)         | `cpu-original` — AoS, extent-19/3    |
//! |                                | innermost loops, compiler-found ILP  |
//! | CPU targetDP (VVL=8)           | `cpu-targetdp-vvl8` — SoA, TLP x ILP |
//! | GPU no-ILP (VVL=1)             | `xla-vvl_block-32` (smallest block)  |
//! | GPU targetDP (VVL=2)           | `xla-vvl_block-best` (tuned block)   |
//! |--------------------------------|--------------------------------------|
//!
//! Expected shapes: targetDP-CPU beats original by ~1.5x (C2); a tuned
//! xla block beats the smallest block (C3 analog). The absolute CPU/XLA
//! ratio is NOT comparable to the paper's C4 (the "GPU" is an
//! interpret-lowered Pallas kernel on a CPU PJRT plugin) — recorded as a
//! known deviation in EXPERIMENTS.md.

use targetdp::bench::Bench;
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::field::soa_to_aos;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::collision::collide_lattice;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;
use targetdp::runtime::Runtime;
use targetdp::targetdp::tlp::TlpPool;

fn main() {
    let vs = d3q19();
    let p = FeParams::default();
    let geom = Geometry::new(32, 32, 32);
    let n = geom.nsites();
    let reps = 5; // collisions per bench iteration

    // shared state
    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 11);
    let mut rng = init::Rng64::new(3);
    let grad: Vec<f64> = (0..3 * n).map(|_| 0.01 * rng.uniform()).collect();
    let lap: Vec<f64> = (0..n).map(|_| 0.01 * rng.uniform()).collect();
    let sites = Some((n * reps) as f64);

    let mut bench = Bench::new("fig1: binary collision, 32^3 D3Q19");
    let pool = TlpPool::default();
    println!("TLP threads = {}", pool.nthreads);

    // --- bar 1: CPU original (AoS, model-extent inner loops) ---
    let f_aos0 = soa_to_aos(&f0, vs.nvel, n);
    let g_aos0 = soa_to_aos(&g0, vs.nvel, n);
    let grad_aos = soa_to_aos(&grad, 3, n);
    let mut f_aos = f_aos0.clone();
    let mut g_aos = g_aos0.clone();
    bench.case("cpu-original(aos)", sites, || {
        for _ in 0..reps {
            targetdp::baseline::collide_aos(vs, &p, &mut f_aos, &mut g_aos,
                                            &grad_aos, &lap, n, &pool);
        }
    });

    // --- bar 2: CPU targetDP (SoA, TLP x ILP, tuned VVL = 8) ---
    let mut f = f0.clone();
    let mut g = g0.clone();
    bench.case("cpu-targetdp-vvl8(soa)", sites, || {
        for _ in 0..reps {
            collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, n, &pool,
                            8, false);
        }
    });

    // --- bars 3 + 4: the accelerator path at smallest vs tuned block ---
    match Runtime::load(Runtime::default_dir()) {
        Ok(mut rt) => {
            // "best" found by the E2 sweep + perf pass P5 (EXPERIMENTS.md)
            for (label, block) in [("xla-vvl_block-32(no-ilp-analog)", 32),
                                   ("xla-vvl_block-best", 4096)] {
                let name = format!("collision_d3q19_n{n}_vvl{block}");
                if rt.ensure_compiled(&name).is_err() {
                    println!("skip {label}: artifact {name} missing");
                    continue;
                }
                bench.case(label, sites, || {
                    for _ in 0..reps {
                        rt.execute(&name, &[&f0, &g0, &grad, &lap]).unwrap();
                    }
                });
            }
        }
        Err(e) => println!("xla bars skipped: {e}"),
    }

    bench.report();

    // the paper's headline ratios
    if let (Some(orig), Some(tdp)) =
        (bench.mean_of("cpu-original(aos)"),
         bench.mean_of("cpu-targetdp-vvl8(soa)"))
    {
        println!("\nC2 CPU speedup targetDP vs original: {:.2}x \
                  (paper: ~1.5x)", orig / tdp);
    }
    if let (Some(b32), Some(best)) =
        (bench.mean_of("xla-vvl_block-32(no-ilp-analog)"),
         bench.mean_of("xla-vvl_block-best"))
    {
        println!("C3 accelerator block tuning: {:.2}x \
                  (paper GPU VVL=2 vs 1: ~1.4x)", b32 / best);
    }
    if let (Some(tdp), Some(best)) =
        (bench.mean_of("cpu-targetdp-vvl8(soa)"),
         bench.mean_of("xla-vvl_block-best"))
    {
        println!("C4 xla/cpu ratio: {:.2}x — NOT comparable to the paper's \
                  4.5x (interpret-mode CPU PJRT, see DESIGN.md section 10)",
                 tdp / best);
    }
}
