//! E7 (extension) — the paper's §V reduction future-work, benchmarked:
//! per-component lattice sum of a 19-component field across targets and
//! VVL values, plus the naive serial loop as reference.

use targetdp::bench::Bench;
use targetdp::targetdp::reduce::reduce_sum;
use targetdp::targetdp::tlp::TlpPool;
use targetdp::runtime::Runtime;

fn main() {
    let n = 32 * 32 * 32;
    let ncomp = 19;
    let field: Vec<f64> =
        (0..ncomp * n).map(|i| ((i % 101) as f64) * 0.5).collect();
    let reps = 20;
    let sites = Some((n * reps) as f64);

    let mut bench = Bench::new("reduction: 19-comp sum, 32^3");

    // naive serial reference
    let mut sink = vec![0.0; ncomp];
    bench.case("serial loop", sites, || {
        for _ in 0..reps {
            for c in 0..ncomp {
                sink[c] = field[c * n..(c + 1) * n].iter().sum();
            }
        }
    });

    let pool = TlpPool::default();
    for vvl in [1usize, 8, 32] {
        bench.case(&format!("targetdp reduce vvl={vvl}"), sites, || {
            for _ in 0..reps {
                reduce_sum(&field, ncomp, n, &pool, vvl, &mut sink);
            }
        });
    }

    match Runtime::load(Runtime::default_dir()) {
        Ok(mut rt) => {
            let name = format!("reduce_sum_c{ncomp}_n{n}");
            if rt.ensure_compiled(&name).is_ok() {
                bench.case("xla reduce artifact", sites, || {
                    for _ in 0..reps {
                        sink = rt.execute(&name, &[&field]).unwrap()
                            .pop()
                            .unwrap();
                    }
                });
            }
        }
        Err(e) => println!("xla reduce skipped: {e}"),
    }

    bench.report();
    // keep `sink` observable so the loops are not optimised away
    println!("checksum: {:.3}", sink.iter().sum::<f64>());
}
