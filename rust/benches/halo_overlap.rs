//! E9 — halo-exchange overlap: bulk-synchronous vs overlapped exchange
//! schedules across rank counts and lattice shapes.
//!
//! Every rank posts its boundary-plane sends, then either (bulk-sync)
//! waits for the halos before touching anything, or (overlapped) sweeps
//! the interior sites — whose stencils provably stay inside the slab —
//! while the planes are in flight and finishes the edge planes on
//! arrival. The schedules move identical bytes and produce identical
//! bits; the only difference is where the wait lands, which is exactly
//! what the MLUPS ratio exposes. Thin slabs (few planes per rank) have
//! the highest exchange-to-compute ratio and show the effect most.
//!
//! Reports BENCH-CSV lines plus `OVERLAP-SPEEDUP` ratios for the
//! experiment scripts.

use targetdp::comms::{run_decomposed, CommsConfig};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;

const RANKS: [usize; 3] = [1, 2, 4];
const STEPS: u64 = 4;

fn label(tag: &str, ranks: usize, mode: &str) -> String {
    format!("{tag} ranks={ranks} {mode}")
}

fn main() {
    let vs = d3q19();
    let p = FeParams::default();
    // (tag, geometry): a compact cube and a thin-slab shape where halo
    // traffic is proportionally heaviest per rank
    let shapes = [("32x16x16", Geometry::new(32, 16, 16)),
                  ("16x32x32", Geometry::new(16, 32, 32))];

    let mut bench = targetdp::bench::Bench::new(
        "halo exchange: bulk-sync vs overlapped, D3Q19");

    for (tag, geom) in &shapes {
        let n = geom.nsites();
        let mut f0 = vec![0.0; vs.nvel * n];
        let mut g0 = vec![0.0; vs.nvel * n];
        init::init_spinodal(vs, &p, geom, &mut f0, &mut g0, 0.05, 7);
        let sites = Some((n as u64 * STEPS) as f64);

        for ranks in RANKS {
            for (mode, overlap) in [("bulk-sync", false),
                                    ("overlapped", true)] {
                let cfg = CommsConfig { ranks, overlap, threads: 0,
                                        ..CommsConfig::default() };
                let mut f = f0.clone();
                let mut g = g0.clone();
                bench.case(&label(tag, ranks, mode), sites, || {
                    run_decomposed(geom, vs, &p, &mut f, &mut g, STEPS,
                                   &cfg)
                        .unwrap();
                });
            }
        }
    }

    bench.report();

    println!();
    for (tag, _) in &shapes {
        for ranks in RANKS {
            let bulk = bench.mean_of(&label(tag, ranks, "bulk-sync"));
            let over = bench.mean_of(&label(tag, ranks, "overlapped"));
            if let (Some(b), Some(o)) = (bulk, over) {
                println!("OVERLAP-SPEEDUP,shape={tag},ranks={ranks},{:.3}",
                         b / o);
            }
        }
    }
}
