//! E9 — halo-exchange overlap: bulk-synchronous vs overlapped exchange
//! schedules across rank counts and lattice shapes.
//!
//! Every rank posts its boundary-plane sends, then either (bulk-sync)
//! waits for the halos before touching anything, or (overlapped) sweeps
//! the interior sites — whose stencils provably stay inside the slab —
//! while the planes are in flight and finishes the edge planes on
//! arrival. The schedules move identical bytes and produce identical
//! bits; the only difference is where the wait lands, which is exactly
//! what the MLUPS ratio exposes. Thin slabs (few planes per rank) have
//! the highest exchange-to-compute ratio and show the effect most.
//!
//! Reports BENCH-CSV lines plus `OVERLAP-SPEEDUP` ratios for the
//! experiment scripts.
//!
//! A second sweep measures **communication-avoiding super-steps**
//! (`CommsConfig::depth`): one depth-`2k` ghost-block exchange per `k`
//! steps instead of `6` plane messages per step, over both transports —
//! in-process channels and real loopback TCP (where the saved
//! per-message syscalls and round-trips matter most). Emits
//! `DEPTH-SPEEDUP` ratios against the depth-1 schedule per transport.
//!
//! A third sweep holds the rank count fixed at 8 on a 32^3 cube and
//! varies only the **grid shape** — slab 8x1x1, pencil 4x2x1, block
//! 2x2x2 — where the decomposition's surface-to-volume ratio, not the
//! schedule, sets the halo traffic. Emits `GRID-SPEEDUP` ratios against
//! the slab plus `HALO-BYTES` totals from the per-rank traffic
//! counters (the block grid must move the fewest bytes).
//!
//! A fourth sweep pits the **hybrid transport** against pure sockets:
//! the same 4-rank world once as 4 loopback TCP endpoints and once as 2
//! simulated host processes of 2 resident ranks each, where co-hosted
//! links ride in-process channels (no framing, no syscalls) and only
//! the host pair crosses TCP. Identical physics and wire frames; the
//! `HYBRID-SPEEDUP` ratio isolates the per-message transport cost the
//! per-link routing removes.

use std::thread;

use targetdp::comms::launcher::{connect_host, connect_rank, RankServer};
use targetdp::comms::{run_decomposed, serve_rank, CommsConfig,
                      CommsWorld, HybridTransport, SocketTransport,
                      Transport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;

const RANKS: [usize; 3] = [1, 2, 4];
const STEPS: u64 = 4;

/// Super-step depths swept by the communication-avoidance experiment
/// (depth 8 needs 16 ghost planes per side, so slabs of >= 16 planes).
const DEPTHS: [usize; 4] = [1, 2, 4, 8];
const DEPTH_RANKS: usize = 2;
const DEPTH_STEPS: u64 = 8;

/// An N-rank + controller socket world on loopback: the production
/// rendezvous, rank endpoints served from threads of this process.
fn loopback_world(nranks: usize)
                  -> (Vec<SocketTransport>, SocketTransport) {
    let server = RankServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = (0..nranks)
        .map(|r| {
            let addr = addr.clone();
            thread::spawn(move || connect_rank(&addr, Some(r)).unwrap())
        })
        .collect();
    let ctl = server.rendezvous(nranks, b"").unwrap();
    let mut ranks: Vec<Option<SocketTransport>> =
        (0..nranks).map(|_| None).collect();
    for j in joins {
        let (t, _payload) = j.join().unwrap();
        let r = t.rank();
        ranks[r] = Some(t);
    }
    (ranks.into_iter().map(Option::unwrap).collect(), ctl)
}

/// The same world as a hybrid rendezvous: two simulated host processes
/// (threads of this process) each carrying half the ranks as resident
/// endpoints — co-hosted links on in-process channels, one TCP stream
/// for the host pair and one per host to the controller.
fn hybrid_world(nranks: usize)
                -> (Vec<HybridTransport>, HybridTransport) {
    let server = RankServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let half = nranks / 2;
    let blocks = [(0usize, half), (half, nranks - half)];
    let joins: Vec<_> = blocks
        .iter()
        .map(|&(first, count)| {
            let addr = addr.clone();
            thread::spawn(move || {
                connect_host(&addr, Some(first), count).unwrap()
            })
        })
        .collect();
    let ctl = server.rendezvous_hosts(nranks, b"").unwrap();
    let mut ranks: Vec<Option<HybridTransport>> =
        (0..nranks).map(|_| None).collect();
    for j in joins {
        let (endpoints, _payload) = j.join().unwrap();
        for t in endpoints {
            let r = t.rank();
            ranks[r] = Some(t);
        }
    }
    (ranks.into_iter().map(Option::unwrap).collect(), ctl)
}

fn label(tag: &str, ranks: usize, mode: &str) -> String {
    format!("{tag} ranks={ranks} {mode}")
}

fn main() {
    let vs = d3q19();
    let p = FeParams::default();
    // (tag, geometry): a compact cube and a thin-slab shape where halo
    // traffic is proportionally heaviest per rank
    let shapes = [("32x16x16", Geometry::new(32, 16, 16)),
                  ("16x32x32", Geometry::new(16, 32, 32))];

    let mut bench = targetdp::bench::Bench::new(
        "halo exchange: bulk-sync vs overlapped, D3Q19");

    for (tag, geom) in &shapes {
        let n = geom.nsites();
        let mut f0 = vec![0.0; vs.nvel * n];
        let mut g0 = vec![0.0; vs.nvel * n];
        init::init_spinodal(vs, &p, geom, &mut f0, &mut g0, 0.05, 7);
        let sites = Some((n as u64 * STEPS) as f64);

        for ranks in RANKS {
            for (mode, overlap) in [("bulk-sync", false),
                                    ("overlapped", true)] {
                let cfg = CommsConfig { ranks, overlap, threads: 0,
                                        ..CommsConfig::default() };
                let mut f = f0.clone();
                let mut g = g0.clone();
                bench.case(&label(tag, ranks, mode), sites, || {
                    run_decomposed(geom, vs, &p, &mut f, &mut g, STEPS,
                                   &cfg)
                        .unwrap();
                });
            }
        }
    }

    bench.report();

    println!();
    for (tag, _) in &shapes {
        for ranks in RANKS {
            let bulk = bench.mean_of(&label(tag, ranks, "bulk-sync"));
            let over = bench.mean_of(&label(tag, ranks, "overlapped"));
            if let (Some(b), Some(o)) = (bulk, over) {
                println!("OVERLAP-SPEEDUP,shape={tag},ranks={ranks},{:.3}",
                         b / o);
            }
        }
    }

    // ---- communication-avoiding super-steps: depth sweep --------------
    // 64 planes over 2 ranks -> 32-plane slabs: deep enough for depth 8
    let geom = Geometry::new(64, 8, 8);
    let n = geom.nsites();
    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 7);
    let sites = Some((n as u64 * DEPTH_STEPS) as f64);

    let mut sweep = targetdp::bench::Bench::new(
        "communication-avoiding super-steps: depth sweep, D3Q19 64x8x8");
    let dlabel = |transport: &str, depth: usize| {
        format!("{transport} depth={depth}")
    };
    for depth in DEPTHS {
        let cfg = CommsConfig { ranks: DEPTH_RANKS, depth, threads: 0,
                                ..CommsConfig::default() };

        // channel transport: the one-shot in-process world
        let mut f = f0.clone();
        let mut g = g0.clone();
        sweep.case(&dlabel("channel", depth), sites, || {
            run_decomposed(&geom, vs, &p, &mut f, &mut g, DEPTH_STEPS,
                           &cfg)
                .unwrap();
        });

        // socket transport: a fresh loopback TCP world per iteration
        // (rendezvous included — identical physics, real syscalls per
        // message, which is exactly what deeper super-steps amortize)
        sweep.case(&dlabel("socket", depth), sites, || {
            let (rank_transports, ctl) = loopback_world(DEPTH_RANKS);
            let world = CommsWorld::new(geom, cfg.clone()).unwrap();
            let mut servers = Vec::new();
            for t in rank_transports {
                let d = world.dec.domains[t.rank()].clone();
                let (f0, g0) = (f0.clone(), g0.clone());
                let cfg = cfg.clone();
                servers.push(thread::spawn(move || {
                    serve_rank(d, vs, &p, f0, g0, &cfg, 1, Box::new(t))
                }));
            }
            let mut session =
                world.remote_session(vs, Box::new(ctl)).unwrap();
            session.advance(DEPTH_STEPS).unwrap();
            session.finish().unwrap();
            for s in servers {
                s.join().unwrap().unwrap();
            }
        });
    }

    sweep.report();

    println!();
    for transport in ["channel", "socket"] {
        let base = sweep.mean_of(&dlabel(transport, 1));
        for depth in DEPTHS {
            let deep = sweep.mean_of(&dlabel(transport, depth));
            if let (Some(b), Some(d)) = (base, deep) {
                println!(
                    "DEPTH-SPEEDUP,transport={transport},ranks={},\
                     depth={depth},{:.3}",
                    DEPTH_RANKS,
                    b / d
                );
            }
        }
    }

    // ---- grid-shape sweep: slab vs pencil vs block at 8 ranks ---------
    // a 32^3 cube, where the block decomposition's surface-to-volume
    // ratio beats the slab's (5832 vs 6144 site payloads per rank per step)
    let geom = Geometry::new(32, 32, 32);
    let n = geom.nsites();
    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 7);
    let sites = Some((n as u64 * STEPS) as f64);

    let mut grids = targetdp::bench::Bench::new(
        "3D Cartesian grid shapes at 8 ranks, D3Q19 32^3");
    let shapes: [(&str, [usize; 3]); 3] = [("slab", [8, 1, 1]),
                                           ("pencil", [4, 2, 1]),
                                           ("block", [2, 2, 2])];
    let mut halo_bytes = Vec::new();
    for (name, grid) in shapes {
        let cfg = CommsConfig { ranks: 8, grid, threads: 0,
                                ..CommsConfig::default() };
        let mut f = f0.clone();
        let mut g = g0.clone();
        let mut bytes = 0u64;
        grids.case(&format!("grid {name}"), sites, || {
            let rep = run_decomposed(&geom, vs, &p, &mut f, &mut g, STEPS,
                                     &cfg)
                .unwrap();
            bytes = rep.ranks.iter().map(|r| r.bytes_sent).sum();
        });
        halo_bytes.push((name, grid, bytes));
    }

    grids.report();

    println!();
    for (name, grid, bytes) in &halo_bytes {
        println!(
            "HALO-BYTES,shape={name},grid={}x{}x{},ranks=8,steps={STEPS},\
             {bytes}",
            grid[0], grid[1], grid[2]
        );
    }
    let slab = grids.mean_of("grid slab");
    for (name, _, _) in &halo_bytes {
        let shaped = grids.mean_of(&format!("grid {name}"));
        if let (Some(s), Some(g)) = (slab, shaped) {
            println!("GRID-SPEEDUP,shape={name},ranks=8,{:.3}", s / g);
        }
    }

    // ---- hybrid vs socket: per-link transport routing -----------------
    // 4 ranks, 2 simulated hosts of 2 resident ranks: the two inner
    // slab faces ride channels, only the middle face crosses TCP —
    // versus the pure-socket world where every face pays framing and
    // syscalls. Fresh rendezvous per iteration on both sides so the
    // setup cost cancels out of the ratio.
    let geom = Geometry::new(64, 8, 8);
    let n = geom.nsites();
    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 7);
    let sites = Some((n as u64 * DEPTH_STEPS) as f64);

    const HYBRID_RANKS: usize = 4;
    const HYBRID_DEPTHS: [usize; 2] = [1, 2];
    let mut hyb = targetdp::bench::Bench::new(
        "hybrid vs socket transport: 4 ranks / 2 hosts, D3Q19 64x8x8");
    for depth in HYBRID_DEPTHS {
        let cfg = CommsConfig { ranks: HYBRID_RANKS, depth, threads: 0,
                                ..CommsConfig::default() };
        for transport in ["socket", "hybrid"] {
            hyb.case(&dlabel(transport, depth), sites, || {
                let world = CommsWorld::new(geom, cfg.clone()).unwrap();
                let mut servers = Vec::new();
                let mut serve = |t: Box<dyn Transport + Send>| {
                    let d = world.dec.domains[t.rank()].clone();
                    let (f0, g0) = (f0.clone(), g0.clone());
                    let cfg = cfg.clone();
                    servers.push(thread::spawn(move || {
                        serve_rank(d, vs, &p, f0, g0, &cfg, 1, t)
                    }));
                };
                let mut session = if transport == "socket" {
                    let (rank_transports, ctl) =
                        loopback_world(HYBRID_RANKS);
                    for t in rank_transports {
                        serve(Box::new(t));
                    }
                    world.remote_session(vs, Box::new(ctl)).unwrap()
                } else {
                    let (rank_transports, ctl) =
                        hybrid_world(HYBRID_RANKS);
                    for t in rank_transports {
                        serve(Box::new(t));
                    }
                    world.remote_session(vs, Box::new(ctl)).unwrap()
                };
                session.advance(DEPTH_STEPS).unwrap();
                session.finish().unwrap();
                for s in servers {
                    s.join().unwrap().unwrap();
                }
            });
        }
    }

    hyb.report();

    println!();
    for depth in HYBRID_DEPTHS {
        let sock = hyb.mean_of(&dlabel("socket", depth));
        let hybm = hyb.mean_of(&dlabel("hybrid", depth));
        if let (Some(s), Some(h)) = (sock, hybm) {
            println!(
                "HYBRID-SPEEDUP,ranks={HYBRID_RANKS},hosts=2,\
                 depth={depth},{:.3}",
                s / h
            );
        }
    }
}
