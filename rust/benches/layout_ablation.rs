//! E3 — the SoA claim (paper section III-B): "Data should be stored in a
//! Structure of Arrays format ... to allow chunks of lattice site data to
//! be loaded as vectors". Crosses layout (SoA vs AoS) with kernel style
//! (scalar vs VVL-chunked) to isolate how much of the Figure-1 gap is
//! layout and how much is the explicit ILP exposure.

use targetdp::bench::Bench;
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::field::soa_to_aos;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::collision::collide_lattice;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;
use targetdp::targetdp::tlp::TlpPool;

fn main() {
    let vs = d3q19();
    let p = FeParams::default();
    let geom = Geometry::new(32, 32, 32);
    let n = geom.nsites();
    let reps = 5;

    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 33);
    let mut rng = init::Rng64::new(5);
    let grad: Vec<f64> = (0..3 * n).map(|_| 0.01 * rng.uniform()).collect();
    let lap: Vec<f64> = (0..n).map(|_| 0.01 * rng.uniform()).collect();
    let sites = Some((n * reps) as f64);
    let pool = TlpPool::default();

    let mut bench = Bench::new("layout ablation: collision 32^3 D3Q19");

    // AoS + model-extent loops (the original-Ludwig structure)
    let f_aos0 = soa_to_aos(&f0, vs.nvel, n);
    let g_aos0 = soa_to_aos(&g0, vs.nvel, n);
    let grad_aos = soa_to_aos(&grad, 3, n);
    let mut f_aos = f_aos0.clone();
    let mut g_aos = g_aos0.clone();
    bench.case("aos + scalar (original)", sites, || {
        for _ in 0..reps {
            targetdp::baseline::collide_aos(vs, &p, &mut f_aos, &mut g_aos,
                                            &grad_aos, &lap, n, &pool);
        }
    });

    // SoA + scalar site loops (layout change only)
    let mut f = f0.clone();
    let mut g = g0.clone();
    bench.case("soa + scalar", sites, || {
        for _ in 0..reps {
            collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, n, &pool,
                            32, true);
        }
    });

    // SoA + VVL chunks (the full targetDP treatment)
    let mut f = f0.clone();
    let mut g = g0.clone();
    bench.case("soa + vvl8 (targetDP)", sites, || {
        for _ in 0..reps {
            collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, n, &pool,
                            8, false);
        }
    });

    bench.report();

    if let (Some(aos), Some(soa), Some(tdp)) =
        (bench.mean_of("aos + scalar (original)"),
         bench.mean_of("soa + scalar"),
         bench.mean_of("soa + vvl8 (targetDP)"))
    {
        println!("\nlayout-only gain (AoS->SoA):     {:.2}x", aos / soa);
        println!("ILP-exposure gain (scalar->VVL): {:.2}x", soa / tdp);
        println!("combined (the Figure-1 C2 bar):  {:.2}x", aos / tdp);
    }
}
