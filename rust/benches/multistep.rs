//! E8 — the host MultiStep temporal-blocking tier: k fused timesteps per
//! launch over cache-resident x-slabs vs the one-step fused `FullStep`.
//! Per k steps, `FullStep` traverses the global f/g state k times (plus k
//! phi/gradient sweeps); the blocked sweep reads and writes the global
//! state once and keeps all intermediate traffic inside the slab scratch,
//! at the price of recomputing the depth-2k overlap planes. A long-thin
//! lattice (many x-planes, small plane cross-section) is the shape the
//! auto planner targets.
//!
//! Reports BENCH-CSV lines plus `MULTISTEP-SPEEDUP` ratios vs `FullStep`
//! for the experiment scripts.

use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::constant::Constant;
use targetdp::targetdp::tlp::{Schedule, TlpPool};
use targetdp::targetdp::{HostTarget, Target};

const THREADS: [usize; 2] = [1, 4];
const KS: [u64; 4] = [1, 2, 4, 8];

fn label(threads: usize, tier: &str) -> String {
    format!("threads={threads} {tier}")
}

/// Host target with the MultiStep knobs pinned. `k == 0` disables the
/// tier outright (a 1 KB planner budget admits no slab), giving a clean
/// `FullStep` baseline on a lattice the auto planner would otherwise
/// claim.
fn make_target(threads: usize, k: u64) -> HostTarget {
    let pool = TlpPool::new(threads, Schedule::Static);
    let mut t = HostTarget::simd(8, pool).unwrap();
    if k > 0 {
        t.copy_constant("multi_step", Constant::Int(k as i64)).unwrap();
    } else {
        t.copy_constant("multi_step_cache_kb", Constant::Int(1)).unwrap();
    }
    t
}

fn main() {
    let model = LatticeModel::D3Q19;
    let vs = model.velset();
    // long-thin: 512 x-planes of 8x8 — ~10 MB of f/g state streamed
    // through ~41 KB planes, the shape temporal blocking amortises
    let geom = Geometry::new(512, 8, 8);
    let n = geom.nsites();
    let steps_per_iter = 8u64; // divisible by every k in KS
    let p = FeParams::default();

    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 31);

    let mut bench = targetdp::bench::Bench::new(
        "host MultiStep temporal blocking: 512x8x8 D3Q19");
    let sites = Some((n as u64 * steps_per_iter) as f64);

    for threads in THREADS {
        for k in std::iter::once(0u64).chain(KS) {
            let tier = if k == 0 {
                "full-step".to_string()
            } else {
                format!("multi-step k={k}")
            };
            let mut target = make_target(threads, k);
            let mut engine =
                LbEngine::new(&mut target, geom, model, p).unwrap();
            engine.load_state(&f0, &g0).unwrap();
            bench.case(&label(threads, &tier), sites, || {
                engine.run(steps_per_iter).unwrap();
            });
        }
    }

    bench.report();

    println!();
    for threads in THREADS {
        let base = bench.mean_of(&label(threads, "full-step"));
        for k in KS {
            let blk = bench
                .mean_of(&label(threads, &format!("multi-step k={k}")));
            if let (Some(b), Some(m)) = (base, blk) {
                println!("MULTISTEP-SPEEDUP,threads={threads},k={k},{:.3}",
                         b / m);
            }
        }
    }
}
