//! Resident vs per-block-respawn rank worlds across logging-block sizes.
//!
//! The PR-3 decomposed driver paid O(global state) memcpy + one thread
//! spawn per rank at **every logging block**: each block was a one-shot
//! `CommsWorld::run` (scatter + spawn + run + gather) followed by a
//! full-state reduction for the observables. The resident session spawns
//! the rank threads once, keeps the state slab-local, and reduces
//! observables as distributed partials — per block only O(ranks) sums
//! travel. The smaller the block (the finer the observable logging), the
//! more the respawn overhead dominates; block = total steps makes the two
//! nearly identical, bounding the resident fixed cost.
//!
//! Reports BENCH-CSV lines plus `RESIDENT-SPEEDUP` ratios (respawn mean /
//! resident mean) for the experiment scripts.

use targetdp::comms::{CommsConfig, CommsWorld};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::state_observables;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;

const STEPS: u64 = 100;
const BLOCKS: [u64; 3] = [1, 10, 100];
const RANKS: usize = 4;

fn main() {
    let vs = d3q19();
    let p = FeParams::default();
    let geom = Geometry::new(32, 16, 16);
    let n = geom.nsites();
    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 7);
    let cfg = CommsConfig { ranks: RANKS, threads: 0,
                            ..CommsConfig::default() };
    let sites = Some((n as u64 * STEPS) as f64);

    let mut bench = targetdp::bench::Bench::new(
        "resident vs per-block-respawn rank worlds, D3Q19 32x16x16");

    for block in BLOCKS {
        // resident: one session for the whole run; per block one Advance
        // command + a distributed observable reduction
        bench.case(&format!("resident block={block}"), sites, || {
            let world = CommsWorld::new(geom, cfg.clone()).unwrap();
            let mut session = world
                .session(vs, &p, f0.clone(), g0.clone())
                .unwrap();
            let mut done = 0;
            while done < STEPS {
                let todo = block.min(STEPS - done);
                session.advance(todo).unwrap();
                session.observables().unwrap();
                done += todo;
            }
            session.finish().unwrap();
        });

        // respawn: the per-block one-shot wrapper — every block pays the
        // driver-side f/g copy into the session (PR 3's borrow-based
        // scatter avoided that copy, so a slice of this gap is the
        // wrapper's copy, the rest is thread spawn + scatter + gather),
        // then a full-state host reduction for the observables
        bench.case(&format!("respawn block={block}"), sites, || {
            let world = CommsWorld::new(geom, cfg.clone()).unwrap();
            let mut f = f0.clone();
            let mut g = g0.clone();
            let mut done = 0;
            while done < STEPS {
                let todo = block.min(STEPS - done);
                world.run(vs, &p, &mut f, &mut g, todo).unwrap();
                let _ = state_observables(vs, &f, &g, n);
                done += todo;
            }
        });
    }

    bench.report();

    println!();
    for block in BLOCKS {
        let resident = bench.mean_of(&format!("resident block={block}"));
        let respawn = bench.mean_of(&format!("respawn block={block}"));
        if let (Some(res), Some(spawn)) = (resident, respawn) {
            println!("RESIDENT-SPEEDUP,ranks={RANKS},block={block},{:.3}",
                     spawn / res);
        }
    }
}
