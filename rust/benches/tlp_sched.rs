//! E5 — launch-geometry tuning: the TLP analog of the paper's TPB (threads
//! per block) knob. Sweeps thread count and static/dynamic chunk
//! scheduling for the collision kernel. On this single-core testbed the
//! thread sweep is structural (no speedup expected — DESIGN.md section 2);
//! the scheduling-overhead comparison is still meaningful.

use targetdp::bench::Bench;
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::collision::collide_lattice;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;
use targetdp::targetdp::tlp::{Schedule, TlpPool};

fn main() {
    let vs = d3q19();
    let p = FeParams::default();
    let geom = Geometry::new(32, 32, 32);
    let n = geom.nsites();
    let reps = 5;

    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 44);
    let mut rng = init::Rng64::new(6);
    let grad: Vec<f64> = (0..3 * n).map(|_| 0.01 * rng.uniform()).collect();
    let lap: Vec<f64> = (0..n).map(|_| 0.01 * rng.uniform()).collect();
    let sites = Some((n * reps) as f64);

    let mut bench = Bench::new("tlp scheduling: collision 32^3 D3Q19");

    for threads in [1usize, 2, 4] {
        for (sname, sched) in [("static", Schedule::Static),
                               ("dyn1", Schedule::Dynamic { batch: 1 }),
                               ("dyn8", Schedule::Dynamic { batch: 8 })] {
            // threads=1 executes inline; scheduling label still recorded
            let pool = TlpPool::new(threads, sched);
            let mut f = f0.clone();
            let mut g = g0.clone();
            bench.case(&format!("threads={threads} {sname}"), sites, || {
                for _ in 0..reps {
                    collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, n,
                                    &pool, 8, false);
                }
            });
        }
    }

    bench.report();

    let avail = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1);
    println!("\navailable parallelism on this box: {avail} \
              (thread sweep is structural when 1)");
}
