//! E4 — the masked-copy mechanism (paper section III-B): transferring only
//! a lattice subset "can be very computationally expensive [in full],
//! especially when the target is an accelerator". Sweeps the selected
//! fraction (halo shells of growing depth) and compares full vs masked
//! transfer on both host and XLA targets, plus the pack/unpack scratch
//! route vs the direct loop route.

use targetdp::bench::Bench;
use targetdp::lattice::geometry::Geometry;
use targetdp::lattice::halo;
use targetdp::targetdp::masked;
use targetdp::targetdp::memory::FieldDesc;
use targetdp::targetdp::target::Target;
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::{HostTarget, XlaTarget};

fn main() {
    let geom = Geometry::new(32, 32, 32);
    let n = geom.nsites();
    let ncomp = 19; // a distribution-sized field
    let host_data: Vec<f64> = (0..ncomp * n).map(|i| i as f64).collect();
    let desc = FieldDesc::new("f", ncomp, n);
    let reps = 10;

    let mut bench = Bench::new("masked copies: 19-comp field, 32^3");

    let mut targets: Vec<(&str, Box<dyn Target>)> = vec![(
        "host",
        Box::new(HostTarget::simd(8, TlpPool::serial()).unwrap()),
    )];
    if let Ok(x) = XlaTarget::from_default_artifacts() {
        targets.push(("xla", Box::new(x)));
    }

    for (tname, target) in targets.iter_mut() {
        let id = target.malloc(&desc).unwrap();
        let mut out = vec![0.0; ncomp * n];

        bench.case(&format!("{tname}: full copyToTarget"), None, || {
            for _ in 0..reps {
                target.copy_to_target(id, &host_data).unwrap();
            }
        });
        bench.case(&format!("{tname}: full copyFromTarget"), None, || {
            for _ in 0..reps {
                target.copy_from_target(id, &mut out).unwrap();
            }
        });

        for depth in [1usize, 2, 4, 8] {
            let mask = halo::boundary_shell(&geom, depth);
            let frac = halo::fill_fraction(&mask);
            bench.case(
                &format!("{tname}: masked to, depth={depth} \
                          ({:.0}% of sites)", 100.0 * frac),
                None,
                || {
                    for _ in 0..reps {
                        target
                            .copy_to_target_masked(id, &host_data, &mask)
                            .unwrap();
                    }
                },
            );
            bench.case(
                &format!("{tname}: masked from, depth={depth}"),
                None,
                || {
                    for _ in 0..reps {
                        target
                            .copy_from_target_masked(id, &mut out, &mask)
                            .unwrap();
                    }
                },
            );
        }
        target.free(id).unwrap();
    }

    // mechanism ablation: pack/scratch route vs direct loops (the paper's
    // CUDA vs C implementations of the same API)
    let mask = halo::boundary_shell(&geom, 1);
    let idx = masked::mask_indices(&mask);
    let mut dst = vec![0.0; ncomp * n];
    bench.case("mechanism: pack+unpack (CUDA route)", None, || {
        for _ in 0..reps {
            let packed = masked::pack(&host_data, n, ncomp, &idx);
            masked::unpack(&mut dst, n, ncomp, &idx, &packed);
        }
    });
    bench.case("mechanism: direct loop (C route)", None, || {
        for _ in 0..reps {
            masked::copy_masked_direct(&mut dst, &host_data, n, ncomp,
                                       &mask);
        }
    });

    bench.report();
}
