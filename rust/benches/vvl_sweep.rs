//! E2 — the VVL tuning claim: "We tune the VVL, with 8 being the optimal
//! value" (CPU) and "we tune VVL to be 2" (GPU). Sweeps the virtual vector
//! length on the host-SIMD target and the Pallas `vvl_block` on the XLA
//! target; the expected *shape* is a rise from VVL=1 to an interior
//! optimum, then flat/decline.

use targetdp::bench::Bench;
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::collision::collide_lattice;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;
use targetdp::runtime::Runtime;
use targetdp::targetdp::ilp::SUPPORTED_VVL;
use targetdp::targetdp::tlp::TlpPool;

fn main() {
    let vs = d3q19();
    let p = FeParams::default();
    let geom = Geometry::new(32, 32, 32);
    let n = geom.nsites();
    let reps = 5;

    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 21);
    let mut rng = init::Rng64::new(4);
    let grad: Vec<f64> = (0..3 * n).map(|_| 0.01 * rng.uniform()).collect();
    let lap: Vec<f64> = (0..n).map(|_| 0.01 * rng.uniform()).collect();
    let sites = Some((n * reps) as f64);
    let pool = TlpPool::default();

    let mut bench = Bench::new("vvl sweep: collision 32^3 D3Q19");

    // host-SIMD target across all supported VVLs (paper Fig. 1 CPU story)
    for &vvl in SUPPORTED_VVL {
        let mut f = f0.clone();
        let mut g = g0.clone();
        bench.case(&format!("host-simd vvl={vvl}"), sites, || {
            for _ in 0..reps {
                collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, n,
                                &pool, vvl, false);
            }
        });
    }
    // the scalar (per-site) path as the VVL-less reference
    {
        let mut f = f0.clone();
        let mut g = g0.clone();
        bench.case("host-scalar", sites, || {
            for _ in 0..reps {
                collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, n,
                                &pool, 32, true);
            }
        });
    }

    // XLA target across Pallas block widths (paper Fig. 1 GPU story)
    match Runtime::load(Runtime::default_dir()) {
        Ok(mut rt) => {
            for block in [32, 64, 128, 256, 512, 1024, 2048, 4096] {
                let name = format!("collision_d3q19_n{n}_vvl{block}");
                if rt.ensure_compiled(&name).is_err() {
                    continue;
                }
                bench.case(&format!("xla vvl_block={block}"), sites, || {
                    for _ in 0..reps {
                        rt.execute(&name, &[&f0, &g0, &grad, &lap]).unwrap();
                    }
                });
            }
        }
        Err(e) => println!("xla sweep skipped: {e}"),
    }

    bench.report();

    // locate optima for the summary line
    let best = |prefix: &str| -> Option<(String, f64)> {
        bench
            .results()
            .iter()
            .filter(|r| r.name.starts_with(prefix))
            .map(|r| (r.name.clone(), r.mean))
            .min_by(|a, b| a.1.total_cmp(&b.1))
    };
    if let Some((name, _)) = best("host-simd") {
        println!("\nhost optimum: {name} (paper: VVL=8)");
    }
    if let Some((name, _)) = best("xla") {
        println!("xla optimum:  {name} (paper GPU: VVL=2)");
    }
}
