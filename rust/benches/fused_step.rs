//! E7 — the host fusion tier: fused `FullStep` (collide→push-stream over
//! the precomputed StreamTable) vs the unfused 5-kernel pipeline, swept
//! over VVL and TLP thread count. The fused sweep performs 2 instead of 4
//! full 19-component f/g traversals per step, so on a memory-bound
//! lattice it should land well above the unfused MLUPS; the persistent
//! TLP worker pool means the thread axis carries no per-launch spawn cost
//! (see `targetdp/tlp.rs`).
//!
//! Reports the usual BENCH-CSV lines plus `FUSED-SPEEDUP` ratio lines the
//! experiment scripts grep for.

use targetdp::bench::Bench;
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::tlp::{Schedule, TlpPool};
use targetdp::targetdp::HostTarget;

const THREADS: [usize; 3] = [1, 2, 4];
const VVLS: [usize; 5] = [1, 2, 4, 8, 16];

fn label(threads: usize, vvl: usize, fused: bool) -> String {
    format!("threads={threads} vvl={vvl} {}",
            if fused { "fused" } else { "unfused" })
}

fn main() {
    let model = LatticeModel::D3Q19;
    let vs = model.velset();
    let geom = Geometry::new(24, 24, 24);
    let n = geom.nsites();
    let steps_per_iter = 2u64;
    let p = FeParams::default();

    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 2024);

    let mut bench = Bench::new("host FullStep fusion: 24^3 D3Q19");
    let sites = Some((n as u64 * steps_per_iter) as f64);

    for threads in THREADS {
        for vvl in VVLS {
            for fused in [false, true] {
                let pool = TlpPool::new(threads, Schedule::Static);
                let mut target = HostTarget::simd(vvl, pool).unwrap();
                let mut engine =
                    LbEngine::new(&mut target, geom, model, p).unwrap();
                engine.set_fusion(fused);
                engine.load_state(&f0, &g0).unwrap();
                bench.case(&label(threads, vvl, fused), sites, || {
                    engine.run(steps_per_iter).unwrap();
                });
            }
        }
    }

    bench.report();

    println!();
    for threads in THREADS {
        for vvl in VVLS {
            let unfused = bench.mean_of(&label(threads, vvl, false));
            let fused = bench.mean_of(&label(threads, vvl, true));
            if let (Some(u), Some(f)) = (unfused, fused) {
                println!("FUSED-SPEEDUP,threads={threads},vvl={vvl},\
                          {:.3}", u / f);
            }
        }
    }
}
