//! Telemetry artifact checker: validates the JSON files a decomposed run
//! writes with `--trace-out` / `--report-json`. Exits nonzero with a
//! message naming the first violated invariant — the CI multidomain
//! smoke runs it against a real 2-process socket run.
//!
//! ```text
//! cargo run --release --example check_trace -- --trace trace.json \
//!     [--report run.json] [--ranks N]
//! ```
//!
//! Checks on the Chrome `trace_event` document:
//! - it parses, and `traceEvents` is an array of objects;
//! - every rank (pid) carries **at least one `wait_recv` and one
//!   `interior` span** — the two phase classes that prove both the
//!   exchange and the compute were timed;
//! - every duration event has `dur >= 0` and a `step` arg;
//! - `--ranks N` additionally pins the distinct pid count to N.
//!
//! Checks on the run report (when `--report` is given): it parses, and
//! every per-rank entry has a complete 12-key phase histogram with
//! non-negative seconds.

use std::process::ExitCode;

use targetdp::obs::trace::TracePhase;
use targetdp::util::cli::Args;
use targetdp::util::json::Json;

fn fail(msg: &str) -> ExitCode {
    eprintln!("check_trace: FAIL: {msg}");
    ExitCode::FAILURE
}

fn main() -> ExitCode {
    let args = Args::parse(std::env::args().skip(1))
        .expect("usage: check_trace --trace FILE [--report FILE] \
                 [--ranks N]");
    let path = match args.get("trace") {
        Some(p) => p.to_string(),
        None => return fail("--trace FILE is required"),
    };
    let want_ranks = args.usize_or("ranks", 0).unwrap();

    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => return fail(&format!("cannot read {path}: {e}")),
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => return fail(&format!("{path} is not valid JSON: {e}")),
    };
    let events = match doc.get("traceEvents").as_array() {
        Ok(a) => a,
        Err(_) => return fail("traceEvents is missing or not an array"),
    };

    // per-pid tallies of the phase classes the timeline must prove
    let mut pids: Vec<usize> = Vec::new();
    let mut waits: Vec<usize> = Vec::new();
    let mut interiors: Vec<usize> = Vec::new();
    let mut nspans = 0usize;
    for ev in events {
        let phase = match ev.get("ph").as_str() {
            Ok(p) => p,
            Err(_) => return fail("event without a \"ph\" field"),
        };
        if phase != "X" {
            continue; // metadata events (process/thread names)
        }
        nspans += 1;
        let pid = match ev.get("pid").as_usize() {
            Ok(p) => p,
            Err(_) => return fail("duration event without a pid"),
        };
        let name = match ev.get("name").as_str() {
            Ok(n) => n,
            Err(_) => return fail("duration event without a name"),
        };
        if TracePhase::ALL.iter().all(|p| p.name() != name) {
            return fail(&format!("unknown phase name {name:?}"));
        }
        match ev.get("dur").as_f64() {
            Ok(d) if d >= 0.0 => {}
            _ => return fail(&format!("pid {pid} {name}: bad dur")),
        }
        if ev.get("args").get("step").as_f64().is_err() {
            return fail(&format!("pid {pid} {name}: missing step arg"));
        }
        let slot = match pids.iter().position(|&p| p == pid) {
            Some(i) => i,
            None => {
                pids.push(pid);
                waits.push(0);
                interiors.push(0);
                pids.len() - 1
            }
        };
        if name == TracePhase::WaitRecv.name() {
            waits[slot] += 1;
        }
        if name == TracePhase::Interior.name() {
            interiors[slot] += 1;
        }
    }
    if pids.is_empty() {
        return fail("no duration events: the run shipped no spans");
    }
    if want_ranks > 0 && pids.len() != want_ranks {
        return fail(&format!("expected {want_ranks} rank pids, found {}",
                             pids.len()));
    }
    for (i, &pid) in pids.iter().enumerate() {
        if waits[i] == 0 {
            return fail(&format!("rank pid {pid} has no wait_recv span"));
        }
        if interiors[i] == 0 {
            return fail(&format!("rank pid {pid} has no interior span"));
        }
    }

    if let Some(report) = args.get("report") {
        let text = match std::fs::read_to_string(report) {
            Ok(t) => t,
            Err(e) => return fail(&format!("cannot read {report}: {e}")),
        };
        let doc = match Json::parse(&text) {
            Ok(d) => d,
            Err(e) => {
                return fail(&format!("{report} is not valid JSON: {e}"))
            }
        };
        let ranks = match doc.get("ranks").as_array() {
            Ok(a) if !a.is_empty() => a,
            _ => return fail("report has no per-rank entries"),
        };
        if want_ranks > 0 && ranks.len() != want_ranks {
            return fail(&format!("report: expected {want_ranks} ranks, \
                                  found {}",
                                 ranks.len()));
        }
        for r in ranks {
            let hist = match r.get("phase_seconds").as_object() {
                Ok(h) => h,
                Err(_) => return fail("rank entry without phase_seconds"),
            };
            for p in TracePhase::ALL {
                match hist.get(p.name()).map(Json::as_f64) {
                    Some(Ok(s)) if s >= 0.0 => {}
                    _ => {
                        return fail(&format!("phase_seconds missing or \
                                              negative for {:?}",
                                             p.name()))
                    }
                }
            }
        }
    }

    println!("check_trace: OK — {} ranks, {nspans} spans ({path})",
             pids.len());
    ExitCode::SUCCESS
}
