//! Rank-parallel decomposition demo: targetDP "in conjunction with MPI"
//! (paper section I), here through the in-process comms subsystem.
//!
//! Splits a 48x16x16 binary-fluid run into x-slab ranks, each on its own
//! thread with its own TLP pool, exchanging serialized halo planes. For
//! every rank count it runs both exchange schedules — bulk-synchronous
//! and overlapped-with-interior-compute — verifies all of them produce
//! *identical* physics (gathered state equal to the 1-rank reference),
//! and prints the per-rank MLUPS plus the compute/exchange-wait
//! breakdown the overlap exists to shrink.
//!
//! ```text
//! cargo run --release --example multidomain [-- --ranks N] [--steps K]
//!                                           [--block B]
//! ```
//!
//! `--ranks N` restricts the sweep to one rank count (the CI smoke runs
//! 2 and 4); the default sweeps 1, 2, 3, 4. `--block B` (B > 0) drives a
//! **resident** session in logging blocks of B steps — rank threads
//! spawned once, a distributed observable reduction at every block
//! boundary, state gathered only at the end — and additionally checks
//! the reduced observables against the gathered-state reduction.

use targetdp::comms::{run_decomposed, CommsConfig, CommsWorld,
                      WorldReport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::state_observables;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;
use targetdp::util::cli::Args;

#[allow(clippy::too_many_arguments)]
fn run_resident(geom: &Geometry, vs: &'static targetdp::lb::model::VelSet,
                p: &FeParams, f0: &[f64], g0: &[f64], steps: u64,
                block: u64, cfg: &CommsConfig)
                -> (Vec<f64>, Vec<f64>, WorldReport) {
    let n = geom.nsites();
    let world = CommsWorld::new(*geom, cfg.clone()).expect("world");
    let mut session = world
        .session(vs, p, f0.to_vec(), g0.to_vec())
        .expect("session");
    let mut done = 0;
    let mut last = None;
    while done < steps {
        let todo = block.min(steps - done);
        session.advance(todo).expect("advance");
        last = Some(session.observables().expect("observables"));
        done += todo;
    }
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    session.gather(&mut f, &mut g).expect("gather");
    let rep = session.finish().expect("finish");

    // the distributed per-block reduction must track the gathered state
    // to summation-order rounding (Observables::from_sums contract)
    if let Some(got) = last {
        let want = state_observables(vs, &f, &g, n);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 + 1e-9 * b.abs();
        assert!(close(got.mass, want.mass)
                    && close(got.phi_total, want.phi_total)
                    && close(got.phi_variance, want.phi_variance),
                "reduced observables diverged from the gathered state");
    }
    (f, g, rep)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1))
        .expect("usage: multidomain [--ranks N] [--steps K] [--threads T] \
                 [--block B]");
    let only_ranks = args.usize_or("ranks", 0).unwrap();
    let steps = args.u64_or("steps", 20).unwrap();
    let threads = args.usize_or("threads", 0).unwrap(); // 0 = machine
    let block = args.u64_or("block", 0).unwrap(); // 0 = one-shot world

    let vs = d3q19();
    let p = FeParams::default();
    let geom = Geometry::new(48, 16, 16);
    let n = geom.nsites();

    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.08, 99);

    println!("48x16x16 D3Q19 binary fluid, {steps} steps, concurrent \
              x-slab ranks{}\n",
             if block > 0 {
                 format!(" (resident session, blocks of {block})")
             } else {
                 String::new()
             });

    let rank_counts: Vec<usize> = if only_ranks > 0 {
        vec![only_ranks]
    } else {
        vec![1, 2, 3, 4]
    };

    // reference: 1 rank, bulk-sync (identical to the single-domain path)
    let mut f_ref = f0.clone();
    let mut g_ref = g0.clone();
    run_decomposed(&geom, vs, &p, &mut f_ref, &mut g_ref, steps,
                   &CommsConfig { ranks: 1, overlap: false, threads,
                                  ..CommsConfig::default() })
        .expect("reference run");

    for &ranks in &rank_counts {
        for overlap in [false, true] {
            let mode = if overlap { "overlapped" } else { "bulk-sync " };
            let cfg = CommsConfig { ranks, overlap, threads,
                                    ..CommsConfig::default() };
            let (f, g, rep) = if block > 0 {
                run_resident(&geom, vs, &p, &f0, &g0, steps, block, &cfg)
            } else {
                let mut f = f0.clone();
                let mut g = g0.clone();
                let rep = run_decomposed(&geom, vs, &p, &mut f, &mut g,
                                         steps, &cfg)
                    .expect("decomposed run");
                (f, g, rep)
            };

            let max_df = f
                .iter()
                .zip(&f_ref)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(f == f_ref && g == g_ref,
                    "ranks={ranks} {mode}: physics must be identical \
                     (max |df| = {max_df:.3e})");

            let bytes: u64 = rep.ranks.iter().map(|r| r.bytes_sent).sum();
            println!(
                "ranks={ranks} {mode}  {:>7.2} MLUPS total  ({:.3} s, \
                 {:.2} MiB exchanged, max |df| = {max_df:.1e})",
                rep.mlups(),
                rep.seconds,
                bytes as f64 / (1024.0 * 1024.0),
            );
            for r in &rep.ranks {
                println!(
                    "    rank {:>2}: {:>7.2} MLUPS  compute {:.3}s  \
                     exchange-wait {:.3}s ({:>4.1}%)",
                    r.rank,
                    r.mlups(),
                    r.compute_s,
                    r.wait_s,
                    100.0 * r.wait_fraction(),
                );
            }
        }
    }

    let plane = geom.ly * geom.lz;
    println!("\nhalo planes per rank: 2 of {plane} sites each — the subset \
              the masked copyToTarget/FromTarget API (E4) and the comms \
              wire format move, {:.1}% of a 4-rank slab",
             100.0 * (2.0 * plane as f64) / (n as f64 / 4.0));
    println!("PASS: all rank counts and both exchange schedules \
              bit-identical{}",
             if block > 0 { " across resident blocks" } else { "" });
}
