//! Domain-decomposition demo: targetDP "in conjunction with MPI"
//! (paper section I). Splits a 48x16x16 binary-fluid run into 1/2/3/4
//! x-slabs with halo exchange, verifies all decompositions produce the
//! *identical* physics, and reports the per-step exchange volume the
//! masked-copy API (section III-B) exists to minimise.
//!
//! ```text
//! cargo run --release --example multidomain
//! ```

use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::decomp::{step_multidomain, MultiDomainScratch,
                                SlabDecomposition};
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::init;
use targetdp::lb::model::d3q19;
use targetdp::targetdp::tlp::TlpPool;

fn main() {
    let vs = d3q19();
    let p = FeParams::default();
    let geom = Geometry::new(48, 16, 16);
    let n = geom.nsites();
    let steps = 20;
    let pool = TlpPool::default();

    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.08, 99);

    println!("48x16x16 D3Q19 binary fluid, {steps} steps, slab \
              decomposition along x\n");
    println!("{:>6} {:>12} {:>16} {:>18}", "ranks", "max |df|",
             "halo sites/rank", "exchange B/step");

    let mut reference: Option<Vec<f64>> = None;
    for ndom in [1usize, 2, 3, 4] {
        let dec = SlabDecomposition::new(geom, ndom).unwrap();
        let mut fl = dec.scatter(&f0, vs.nvel);
        let mut gl = dec.scatter(&g0, vs.nvel);
        let mut scratch = MultiDomainScratch::new(&dec, vs.nvel);
        let t = std::time::Instant::now();
        for _ in 0..steps {
            step_multidomain(&dec, vs, &p, &mut fl, &mut gl, &mut scratch,
                             &pool, 8);
        }
        let dt = t.elapsed().as_secs_f64();
        let f = dec.gather(&fl, vs.nvel);

        let diff = match &reference {
            None => {
                reference = Some(f);
                0.0
            }
            Some(r) => f
                .iter()
                .zip(r)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max),
        };
        // 2 halo planes per rank, exchanged twice per step, f and g
        let plane = geom.ly * geom.lz;
        let bytes = 2 * 2 * 2 * plane * vs.nvel * 8;
        println!("{ndom:>6} {diff:>12.2e} {:>16} {bytes:>15} B  \
                  ({:.2} s)", 2 * plane, dt);
        assert!(diff < 1e-12, "decomposition must not change physics");
    }

    println!("\nhalo fraction at 4 ranks: {:.1}% of sites — the subset the \
              masked copyToTarget/FromTarget API transfers (E4)",
             100.0 * (2.0 * (geom.ly * geom.lz) as f64)
                 / (n as f64 / 4.0));
    println!("PASS: all decompositions bit-identical");
}
