//! Rank-parallel decomposition demo: targetDP "in conjunction with MPI"
//! (paper section I), through the comms subsystem — in-process rank
//! threads by default, real rank OS processes over TCP with
//! `--transport socket`.
//!
//! Splits a 48x16x16 binary-fluid run into x-slab ranks, each with its
//! own TLP pool, exchanging serialized halo planes. For every rank count
//! it runs both exchange schedules — bulk-synchronous and
//! overlapped-with-interior-compute — verifies all of them produce
//! *identical* physics (gathered state equal to the 1-rank reference),
//! and prints the per-rank MLUPS plus the compute/exchange-wait
//! breakdown the overlap exists to shrink.
//!
//! ```text
//! cargo run --release --example multidomain [-- --ranks N] [--steps K]
//!                                           [--block B] [--comms-depth D]
//!                                           [--grid PX,PY,PZ]
//!                                           [--transport channel|socket
//!                                                        |hybrid]
//! ```
//!
//! `--ranks N` restricts the sweep to one rank count (the CI smoke runs
//! 2 and 4); the default sweeps 1, 2, 3, 4. `--grid PX,PY,PZ` fixes the
//! rank count to `PX·PY·PZ` and runs every schedule **twice** — once on
//! the slab grid, once on the 3D Cartesian grid — asserting
//! grid == slab == single-domain bitwise (the CI smoke runs a 2x2x1
//! channel grid and a 1x2x1 two-process socket grid). `--block B` (B > 0) drives a
//! **resident** session in logging blocks of B steps — ranks spawned
//! once, a distributed observable reduction at every block boundary,
//! state gathered only at the end — and additionally checks the reduced
//! observables against the gathered-state reduction. `--comms-depth D`
//! (D > 1) turns on communication-avoiding super-steps: one depth-`2D`
//! ghost-block exchange per `D` steps, still bit-identical to the
//! depth-1 reference (the CI smoke runs depth 2 on both transports).
//!
//! `--transport socket` promotes each rank to an OS process on loopback:
//! the example re-executes itself in a child role (`--rank-child`), the
//! processes rendezvous through `comms::launcher`, and the gathered
//! state must *still* be bit-identical to the in-process reference —
//! the CI smoke runs this with 2 processes.
//!
//! `--kill-step S` (with `--kill-rank R`, `--checkpoint-every C`,
//! `--transport socket`) runs the **kill-and-resume** scenario instead
//! of the sweep: a socket world armed with the deterministic fault
//! injection hook checkpoints every C logging blocks until rank R dies
//! at step S, then a second world of fresh processes restores from the
//! last checkpoint, finishes the run, and the final state must be
//! bit-identical to an uninterrupted reference — the CI smoke runs
//! 2 processes, blocks of 2, a checkpoint every 2 blocks and a kill at
//! step 5.
//!
//! `--transport hybrid` runs the one-process-per-**host** shape: the
//! ranks are split over two simulated hosts (distinct `TARGETDP_HOST`
//! tags on loopback), each child carries its block as resident threads,
//! co-hosted neighbours exchange frames in-process and only the
//! host-pair link uses TCP. On top of bitwise parity the run asserts
//! the per-link traffic receipt: intra-host and inter-host bytes both
//! flow (when the shape has both kinds of link) and their sum accounts
//! for every halo byte — the CI smoke runs this as 2 hosts x 2 ranks.

use std::time::Duration;

use targetdp::comms::launcher::{connect_world, HostSpec, LocalRanks,
                                RankServer, WorldEndpoints};
use targetdp::comms::{run_decomposed, serve_rank, Checkpoint,
                      CheckpointField, CommsConfig, CommsWorld, FaultPoint,
                      FaultSpec, Transport, WorldReport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::state_observables;
use targetdp::lb::init;
use targetdp::lb::model::{d3q19, VelSet};
use targetdp::targetdp::tlp::threads_per_rank;
use targetdp::util::cli::Args;

/// The one lattice + initial condition every process derives
/// independently (the initialiser is deterministic, so parent and rank
/// children agree bitwise).
fn setup(vs: &VelSet) -> (Geometry, Vec<f64>, Vec<f64>) {
    let geom = Geometry::new(48, 16, 16);
    let n = geom.nsites();
    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &FeParams::default(), &geom, &mut f0, &mut g0,
                        0.08, 99);
    (geom, f0, g0)
}

/// Parse a `PX,PY,PZ` grid argument (`[0, 0, 0]` = the slab default).
fn parse_grid(spec: &str) -> [usize; 3] {
    let parts: Vec<usize> = spec
        .split(',')
        .map(|p| p.trim().parse().expect("--grid wants PX,PY,PZ"))
        .collect();
    assert_eq!(parts.len(), 3, "--grid wants PX,PY,PZ");
    [parts[0], parts[1], parts[2]]
}

/// Child role (`--rank-child`, spawned by the socket and hybrid paths):
/// rendezvous with the parent and serve one rank — or, with
/// `--local-ranks N > 1`, a whole host block of N resident rank threads
/// — until Shutdown.
fn rank_child(args: &Args) {
    let server = args.get("connect").expect("child needs --connect");
    let rank = args.usize_or("rank", 0).unwrap();
    let ranks = args.usize_or("ranks", 1).unwrap();
    let local = args.usize_or("local-ranks", 1).unwrap();
    let overlap = args.bool_or("overlap", true).unwrap();
    let threads = args.usize_or("threads", 0).unwrap();
    let depth = args.usize_or("comms-depth", 1).unwrap();
    let grid = parse_grid(&args.str_or("grid", "0,0,0"));
    let vs = d3q19();
    let (geom, mut f0, mut g0) = setup(vs);
    // kill-and-resume scenario plumbing: the parent arms the fault and
    // ships the checkpoint path; each child restores its own copy of the
    // global state and keeps only its planes, like the fresh initialiser
    let restore = args.str_or("restore", "");
    if !restore.is_empty() {
        let mut ck = Checkpoint::read_file(std::path::Path::new(&restore))
            .expect("read checkpoint");
        let want = vs.nvel * geom.nsites();
        f0 = ck.take_field("f", want).expect("checkpoint f");
        g0 = ck.take_field("g", want).expect("checkpoint g");
    }
    let kill_step = args.u64_or("kill-step", 0).unwrap();
    let fault = if kill_step > 0 {
        Some(FaultSpec {
            rank: args.usize_or("kill-rank", 0).unwrap(),
            step: kill_step,
            point: match args.str_or("kill-point", "step").as_str() {
                "mid" => FaultPoint::Mid,
                "barrier" => FaultPoint::Barrier,
                _ => FaultPoint::Step,
            },
        })
    } else {
        None
    };
    let wt = args.u64_or("wait-timeout", 0).unwrap();
    let cfg = CommsConfig {
        ranks, overlap, threads, depth, grid, fault,
        wait_timeout: Duration::from_secs(if wt == 0 { 120 } else { wt }),
        ..CommsConfig::default()
    };
    let world = CommsWorld::new(geom, cfg.clone()).expect("world");
    let nthreads = threads_per_rank(threads, ranks);
    let (endpoints, _payload) =
        connect_world(server, Some(rank), local).expect("rendezvous");
    match endpoints {
        WorldEndpoints::Socket(transport) => {
            let d = world.dec.domains[transport.rank()].clone();
            serve_rank(d, vs, &FeParams::default(), f0, g0, &cfg,
                       nthreads, Box::new(transport))
                .expect("serve rank");
        }
        WorldEndpoints::Hybrid(eps) => {
            // hybrid host process: one resident thread per carried rank
            let mut joins = Vec::new();
            for t in eps {
                let d = world.dec.domains[t.rank()].clone();
                let (f0, g0) = (f0.clone(), g0.clone());
                let cfg = cfg.clone();
                joins.push(std::thread::spawn(move || {
                    serve_rank(d, vs, &FeParams::default(), f0, g0, &cfg,
                               nthreads, Box::new(t))
                }));
            }
            for j in joins {
                j.join().unwrap().expect("serve rank");
            }
        }
    }
}

/// Drive a resident session (blocks of `block` steps, one-shot when
/// `block >= steps`) and return the gathered final state + report.
fn drive(mut session: targetdp::comms::CommsSession,
         vs: &'static VelSet, n: usize, steps: u64, block: u64,
         check_reduced: bool)
         -> (Vec<f64>, Vec<f64>, WorldReport) {
    let block = if block > 0 { block } else { steps };
    let mut done = 0;
    let mut last = None;
    while done < steps {
        let todo = block.min(steps - done);
        session.advance(todo).expect("advance");
        last = Some(session.observables().expect("observables"));
        done += todo;
    }
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    session.gather(&mut f, &mut g).expect("gather");
    let rep = session.finish().expect("finish");

    // the distributed per-block reduction must track the gathered state
    // to summation-order rounding (Observables::from_sums contract)
    if check_reduced {
        if let Some(got) = last {
            let want = state_observables(vs, &f, &g, n);
            let close =
                |a: f64, b: f64| (a - b).abs() <= 1e-12 + 1e-9 * b.abs();
            assert!(close(got.mass, want.mass)
                        && close(got.phi_total, want.phi_total)
                        && close(got.phi_variance, want.phi_variance),
                    "reduced observables diverged from the gathered state");
        }
    }
    (f, g, rep)
}

/// One run over rank OS processes on loopback: bind the rendezvous
/// server, re-execute this example `ranks` times in the child role, and
/// drive the remote session exactly like the in-process one.
fn run_socket(geom: &Geometry, vs: &'static VelSet, steps: u64, block: u64,
              cfg: &CommsConfig) -> (Vec<f64>, Vec<f64>, WorldReport) {
    let server = RankServer::bind("127.0.0.1:0").expect("bind rank server");
    let addr = server.local_addr().expect("rank server addr").to_string();
    let extra = vec!["--rank-child".to_string(),
                     "--ranks".to_string(), cfg.ranks.to_string(),
                     "--overlap".to_string(), cfg.overlap.to_string(),
                     "--threads".to_string(), cfg.threads.to_string(),
                     "--comms-depth".to_string(), cfg.depth.to_string(),
                     "--grid".to_string(),
                     format!("{},{},{}", cfg.grid[0], cfg.grid[1],
                             cfg.grid[2])];
    let local = LocalRanks::spawn(cfg.ranks, &addr, &extra)
        .expect("spawn rank processes");
    let controller =
        server.rendezvous(cfg.ranks, &[]).expect("rendezvous");
    let world = CommsWorld::new(*geom, cfg.clone()).expect("world");
    let session = world
        .remote_session(vs, Box::new(controller))
        .expect("remote session");
    let out = drive(session, vs, geom.nsites(), steps, block, block > 0);
    local.wait().expect("rank processes exited cleanly");
    out
}

/// Split `ranks` over two simulated hosts (distinct `TARGETDP_HOST`
/// tags on loopback) — or a single host when there is only one rank.
/// With the z-fastest rank numbering an even first/second split keeps
/// the inner-axis faces co-hosted, so the highest-traffic links land on
/// in-process channels.
fn host_blocks(ranks: usize) -> Vec<HostSpec> {
    let tag = |name: &str| {
        vec![("TARGETDP_HOST".to_string(), name.to_string())]
    };
    if ranks < 2 {
        return vec![HostSpec { first: 0, count: ranks, env: tag("hostA") }];
    }
    let half = ranks / 2;
    vec![HostSpec { first: 0, count: half, env: tag("hostA") },
         HostSpec { first: half, count: ranks - half, env: tag("hostB") }]
}

/// One run over host OS processes on loopback (hybrid transport): the
/// ranks split over two simulated hosts, each child carrying its block
/// as resident rank threads. Beyond bitwise parity (checked by the
/// caller) this asserts the per-link traffic receipt: every rank's
/// intra/inter split sums to its totals, co-hosted neighbours really
/// exchanged in-process bytes, and the host pair really crossed the
/// socket.
fn run_hybrid(geom: &Geometry, vs: &'static VelSet, steps: u64, block: u64,
              cfg: &CommsConfig) -> (Vec<f64>, Vec<f64>, WorldReport) {
    let server = RankServer::bind("127.0.0.1:0").expect("bind rank server");
    let addr = server.local_addr().expect("rank server addr").to_string();
    let extra = vec!["--rank-child".to_string(),
                     "--ranks".to_string(), cfg.ranks.to_string(),
                     "--overlap".to_string(), cfg.overlap.to_string(),
                     "--threads".to_string(), cfg.threads.to_string(),
                     "--comms-depth".to_string(), cfg.depth.to_string(),
                     "--grid".to_string(),
                     format!("{},{},{}", cfg.grid[0], cfg.grid[1],
                             cfg.grid[2])];
    let hosts = host_blocks(cfg.ranks);
    let local = LocalRanks::spawn_hosts(&hosts, &addr, &extra)
        .expect("spawn host processes");
    let controller =
        server.rendezvous_hosts(cfg.ranks, &[]).expect("rendezvous");
    let world = CommsWorld::new(*geom, cfg.clone()).expect("world");
    let session = world
        .remote_session(vs, Box::new(controller))
        .expect("remote session");
    let out = drive(session, vs, geom.nsites(), steps, block, block > 0);
    local.wait().expect("host processes exited cleanly");

    let rep = &out.2;
    for r in &rep.ranks {
        assert_eq!(r.bytes_intra + r.bytes_inter, r.bytes_sent,
                   "rank {}: per-link byte split must sum to the total",
                   r.rank);
        assert_eq!(r.msgs_intra + r.msgs_inter, r.msgs_sent,
                   "rank {}: per-link message split must sum to the total",
                   r.rank);
    }
    let intra: u64 = rep.ranks.iter().map(|r| r.bytes_intra).sum();
    let inter: u64 = rep.ranks.iter().map(|r| r.bytes_inter).sum();
    if hosts.iter().any(|h| h.count > 1) {
        assert!(intra > 0,
                "co-hosted ranks exchanged no in-process bytes");
    }
    if hosts.len() > 1 && cfg.ranks > 1 {
        assert!(inter > 0, "the host pair exchanged no socket bytes");
    }
    const MIB: f64 = 1024.0 * 1024.0;
    println!("    per-link split: {:.2} MiB intra-host (channels), \
              {:.2} MiB inter-host (sockets)",
             intra as f64 / MIB, inter as f64 / MIB);
    out
}

/// The kill-and-resume scenario (`--kill-step S`): prove the
/// checkpoint/fault-tolerance layer end to end over real OS processes.
/// Run 1 is a socket world armed with the deterministic fault hook,
/// checkpointing every `every` logging blocks until the injected death
/// surfaces as a world error; run 2 spawns fresh rank processes that
/// restore from the last checkpoint and finish the remaining steps. The
/// final gathered state must be bit-identical to an uninterrupted
/// in-process reference — same invariant as every other schedule here.
#[allow(clippy::too_many_arguments)]
fn run_kill_and_resume(geom: &Geometry, vs: &'static VelSet, f0: &[f64],
                       g0: &[f64], steps: u64, block: u64, ranks: usize,
                       threads: usize, kill_rank: usize, kill_step: u64,
                       kill_point: &str, every: u64) {
    let n = geom.nsites();
    let block = if block > 0 { block } else { 1 };
    let every = if every > 0 { every } else { 1 };

    // uninterrupted reference: 1 rank, in-process
    let mut f_ref = f0.to_vec();
    let mut g_ref = g0.to_vec();
    run_decomposed(geom, vs, &FeParams::default(), &mut f_ref, &mut g_ref,
                   steps,
                   &CommsConfig { ranks: 1, overlap: false, threads,
                                  ..CommsConfig::default() })
        .expect("reference run");

    let ck_path = std::env::temp_dir()
        .join(format!("multidomain-ck-{}.tdpk", std::process::id()));
    let ck_str = ck_path.to_string_lossy().into_owned();
    let child_args = |restore: &str, armed: bool| {
        let mut e = vec!["--rank-child".to_string(),
                         "--ranks".to_string(), ranks.to_string(),
                         "--threads".to_string(), threads.to_string(),
                         "--wait-timeout".to_string(), "5".to_string()];
        if armed {
            e.extend(["--kill-rank".to_string(), kill_rank.to_string(),
                      "--kill-step".to_string(), kill_step.to_string(),
                      "--kill-point".to_string(), kill_point.to_string()]);
        }
        if !restore.is_empty() {
            e.extend(["--restore".to_string(), restore.to_string()]);
        }
        e
    };

    println!("run 1: {ranks}-process socket world armed to kill rank \
              {kill_rank} at step {kill_step} ({kill_point}), \
              checkpoint every {every} block(s) of {block}");
    let fault = Some(FaultSpec {
        rank: kill_rank,
        step: kill_step,
        point: match kill_point {
            "mid" => FaultPoint::Mid,
            "barrier" => FaultPoint::Barrier,
            _ => FaultPoint::Step,
        },
    });
    let cfg = CommsConfig { ranks, threads, fault,
                            wait_timeout: Duration::from_secs(5),
                            ..CommsConfig::default() };
    let server = RankServer::bind("127.0.0.1:0").expect("bind rank server");
    let addr = server.local_addr().expect("rank server addr").to_string();
    let local = LocalRanks::spawn(ranks, &addr, &child_args("", true))
        .expect("spawn rank processes");
    let controller = server.rendezvous(ranks, &[]).expect("rendezvous");
    let world = CommsWorld::new(*geom, cfg.clone()).expect("world");
    let mut session = world
        .remote_session(vs, Box::new(controller))
        .expect("remote session");

    let dims = [geom.lx as u64, geom.ly as u64, geom.lz as u64];
    let mut done = 0u64;
    let mut blocks = 0u64;
    let mut ck_step = 0u64;
    let died = loop {
        assert!(done < steps, "the injected fault never fired");
        let todo = block.min(steps - done);
        if let Err(e) = session.advance(todo) {
            break e;
        }
        if let Err(e) = session.observables() {
            break e;
        }
        done += todo;
        blocks += 1;
        if blocks % every == 0 && done < steps {
            let mut f = vec![0.0; vs.nvel * n];
            let mut g = vec![0.0; vs.nvel * n];
            if let Err(e) = session.checkpoint(&mut f, &mut g) {
                break e;
            }
            let nvel = vs.nvel as u32;
            Checkpoint {
                step: done,
                dims,
                nvel,
                config_toml: String::new(),
                fields: vec![
                    CheckpointField { name: "f".into(), ncomp: nvel,
                                      data: f },
                    CheckpointField { name: "g".into(), ncomp: nvel,
                                      data: g },
                ],
            }
            .write_file(&ck_path)
            .expect("write checkpoint");
            ck_step = done;
            println!("  checkpoint at step {done} -> {ck_str}");
        }
    };
    println!("  world died as injected: {died}");
    drop(session);
    // the killed rank exits nonzero by design; its neighbours bail on
    // the broken link — ignore the exit statuses, the error above is
    // the receipt
    let _ = local.wait();
    assert!(ck_step > 0,
            "no checkpoint landed before the fault (kill_step \
             {kill_step} fires before checkpoint_every {every} x block \
             {block} steps)");

    println!("run 2: fresh processes resume {} remaining step(s) from \
              the step-{ck_step} checkpoint",
             steps - ck_step);
    let cfg = CommsConfig { ranks, threads,
                            wait_timeout: Duration::from_secs(5),
                            ..CommsConfig::default() };
    let server = RankServer::bind("127.0.0.1:0").expect("bind rank server");
    let addr = server.local_addr().expect("rank server addr").to_string();
    let local = LocalRanks::spawn(ranks, &addr, &child_args(&ck_str, false))
        .expect("spawn rank processes");
    let controller = server.rendezvous(ranks, &[]).expect("rendezvous");
    let world = CommsWorld::new(*geom, cfg.clone()).expect("world");
    let session = world
        .remote_session(vs, Box::new(controller))
        .expect("remote session");
    let (f, g, _rep) = drive(session, vs, n, steps - ck_step, block, false);
    local.wait().expect("resumed rank processes exited cleanly");
    let _ = std::fs::remove_file(&ck_path);

    let max_df = f
        .iter()
        .zip(&f_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(f == f_ref && g == g_ref,
            "kill-and-resume diverged from the uninterrupted run \
             (max |df| = {max_df:.3e})");
    println!("PASS: killed at step {kill_step}, resumed from the step-\
              {ck_step} checkpoint, final state bit-identical to the \
              uninterrupted run (max |df| = {max_df:.1e})");
}

fn main() {
    let args = Args::parse(std::env::args().skip(1))
        .expect("usage: multidomain [--ranks N] [--steps K] [--threads T] \
                 [--block B] [--comms-depth D] [--grid PX,PY,PZ] \
                 [--transport channel|socket|hybrid] \
                 [--kill-rank R --kill-step S [--kill-point P] \
                 --checkpoint-every C]");
    if args.has("rank-child") {
        rank_child(&args);
        return;
    }
    let only_ranks = args.usize_or("ranks", 0).unwrap();
    let steps = args.u64_or("steps", 20).unwrap();
    let threads = args.usize_or("threads", 0).unwrap(); // 0 = machine
    let block = args.u64_or("block", 0).unwrap(); // 0 = one-shot world
    let depth = args.usize_or("comms-depth", 1).unwrap();
    let grid_spec = args.str_or("grid", "");
    let grid3d: Option<[usize; 3]> = if grid_spec.is_empty() {
        None
    } else {
        let g = parse_grid(&grid_spec);
        assert!(g.iter().all(|&p| p > 0), "--grid wants positive PX,PY,PZ");
        assert!(depth == 1 || (g[1] == 1 && g[2] == 1),
                "--comms-depth > 1 needs the slab grid");
        Some(g)
    };
    let transport = args.str_or("transport", "channel");
    match transport.as_str() {
        "channel" | "socket" | "hybrid" => {}
        other => {
            panic!("--transport {other:?}: want channel, socket or hybrid")
        }
    }
    let socket = transport == "socket";
    let hybrid = transport == "hybrid";

    let vs = d3q19();
    let (geom, f0, g0) = setup(vs);
    let n = geom.nsites();

    let kill_step = args.u64_or("kill-step", 0).unwrap();
    if kill_step > 0 {
        assert!(socket, "--kill-step drives the kill-and-resume \
                         scenario over --transport socket");
        let ranks = if only_ranks > 0 { only_ranks } else { 2 };
        run_kill_and_resume(&geom, vs, &f0, &g0, steps, block, ranks,
                            threads,
                            args.usize_or("kill-rank", 0).unwrap(),
                            kill_step,
                            &args.str_or("kill-point", "step"),
                            args.u64_or("checkpoint-every", 1).unwrap());
        return;
    }

    println!("48x16x16 D3Q19 binary fluid, {steps} steps, concurrent \
              ranks{}{}{}{}\n",
             match grid3d {
                 Some(g) => format!(" on a {}x{}x{} Cartesian grid (vs \
                                     the slab)", g[0], g[1], g[2]),
                 None => " on the x-slab grid".to_string(),
             },
             if socket { " as OS processes (socket transport)" }
             else if hybrid {
                 " as 2 simulated host processes (hybrid transport)"
             } else { "" },
             if block > 0 {
                 format!(" (resident session, blocks of {block})")
             } else {
                 String::new()
             },
             if depth > 1 {
                 format!(" (super-steps of {depth}: one ghost-block \
                          exchange per {depth} steps)")
             } else {
                 String::new()
             });

    let rank_counts: Vec<usize> = if let Some(g) = grid3d {
        let p = g.iter().product();
        assert!(only_ranks == 0 || only_ranks == p,
                "--ranks {only_ranks} contradicts --grid {grid_spec} \
                 ({p} ranks)");
        vec![p]
    } else if only_ranks > 0 {
        vec![only_ranks]
    } else {
        vec![1, 2, 3, 4]
    };

    // reference: 1 rank, bulk-sync, in-process (identical to the
    // single-domain path) — the socket runs must match it bitwise too
    let mut f_ref = f0.clone();
    let mut g_ref = g0.clone();
    run_decomposed(&geom, vs, &FeParams::default(), &mut f_ref, &mut g_ref,
                   steps,
                   &CommsConfig { ranks: 1, overlap: false, threads,
                                  ..CommsConfig::default() })
        .expect("reference run");

    // when --grid is given every schedule runs on both shapes: the 3D
    // grid must match the slab world, which must match the reference
    let shapes: Vec<([usize; 3], &str)> = match grid3d {
        Some(g) => vec![([0, 0, 0], "slab"), (g, "grid")],
        None => vec![([0, 0, 0], "slab")],
    };
    for &ranks in &rank_counts {
        for overlap in [false, true] {
        for &(shape, shape_name) in &shapes {
            let mode = if overlap { "overlapped" } else { "bulk-sync " };
            let cfg = CommsConfig { ranks, overlap, threads, depth,
                                    grid: shape,
                                    ..CommsConfig::default() };
            let (f, g, rep) = if hybrid {
                run_hybrid(&geom, vs, steps, block, &cfg)
            } else if socket {
                run_socket(&geom, vs, steps, block, &cfg)
            } else if block > 0 {
                let world =
                    CommsWorld::new(geom, cfg.clone()).expect("world");
                let session = world
                    .session(vs, &FeParams::default(), f0.clone(),
                             g0.clone())
                    .expect("session");
                drive(session, vs, n, steps, block, true)
            } else {
                let mut f = f0.clone();
                let mut g = g0.clone();
                let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                         &mut f, &mut g, steps, &cfg)
                    .expect("decomposed run");
                (f, g, rep)
            };

            let max_df = f
                .iter()
                .zip(&f_ref)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(f == f_ref && g == g_ref,
                    "ranks={ranks} {shape_name} {mode}: physics must be \
                     identical (max |df| = {max_df:.3e})");

            let bytes: u64 = rep.ranks.iter().map(|r| r.bytes_sent).sum();
            println!(
                "ranks={ranks} {shape_name} {mode}  {:>7.2} MLUPS total  \
                 ({:.3} s, {:.2} MiB exchanged, max |df| = {max_df:.1e})",
                rep.mlups(),
                rep.seconds,
                bytes as f64 / (1024.0 * 1024.0),
            );
            for r in &rep.ranks {
                println!(
                    "    rank {:>2}: {:>7.2} MLUPS  compute {:.3}s  \
                     exchange-wait {:.3}s ({:>4.1}%)",
                    r.rank,
                    r.mlups(),
                    r.compute_s,
                    r.wait_s,
                    100.0 * r.wait_fraction(),
                );
            }
        }
        }
    }

    let plane = geom.ly * geom.lz;
    println!("\nhalo planes per rank: 2 of {plane} sites each — the subset \
              the masked copyToTarget/FromTarget API (E4) and the comms \
              wire format move, {:.1}% of a 4-rank slab",
             100.0 * (2.0 * plane as f64) / (n as f64 / 4.0));
    println!("PASS: all rank counts and both exchange schedules \
              bit-identical{}{}{}{}",
             if grid3d.is_some() {
                 " across slab and 3D Cartesian grids"
             } else { "" },
             if block > 0 { " across resident blocks" } else { "" },
             if depth > 1 {
                 " across communication-avoiding super-steps"
             } else { "" },
             if socket { " across rank OS processes" }
             else if hybrid {
                 " across hybrid host processes (per-link intra/inter \
                  split verified)"
             } else { "" });
}
