//! Quickstart: the paper's section-III running example — scale a 3-vector
//! lattice field by a constant — through the complete targetDP API on
//! every available target.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! This is the Rust rendering of the paper's host-code sequence:
//!
//! ```c
//! targetMalloc((void **) &t_field, datasize);
//! copyToTarget(t_field, field, datasize);
//! copyConstantDoubleToTarget(&t_a, &a, sizeof(double));
//! scale TARGET_LAUNCH(N) (t_field);
//! syncTarget();
//! copyFromTarget(field, t_field, datasize);
//! targetFree(t_field);
//! ```

use targetdp::lattice::geometry::Geometry;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::constant::Constant;
use targetdp::targetdp::memory::FieldDesc;
use targetdp::targetdp::target::{KernelId, LaunchArgs, Target};
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::{HostTarget, XlaTarget};

fn scale_on(target: &mut dyn Target, field: &mut [f64], nsites: usize,
            a: f64) -> targetdp::Result<()> {
    let desc = FieldDesc::new("field", 3, nsites);

    // targetMalloc + copyToTarget
    let t_field = target.malloc(&desc)?;
    target.copy_to_target(t_field, field)?;

    // copyConstantDoubleToTarget
    target.copy_constant("scale_a", Constant::Double(a))?;

    // scale TARGET_LAUNCH(N) (t_field); syncTarget()
    let args = LaunchArgs::new(Geometry::new(16, 16, 16),
                               LatticeModel::D3Q19)
        .bind("field", t_field);
    target.launch(KernelId::Scale, &args)?;
    target.sync()?;

    // copyFromTarget + targetFree
    target.copy_from_target(t_field, field)?;
    target.free(t_field)
}

fn main() -> targetdp::Result<()> {
    let nsites = 4096; // matches the shipped scale artifact
    let a = 1.5;

    let make_field =
        || -> Vec<f64> { (0..3 * nsites).map(|i| i as f64 * 0.25).collect() };
    let expect: Vec<f64> = make_field().iter().map(|v| a * v).collect();

    // 1) host, scalar mode (original-code analog)
    let mut scalar = HostTarget::scalar(TlpPool::serial());
    let mut field = make_field();
    scale_on(&mut scalar, &mut field, nsites, a)?;
    assert_eq!(field, expect);
    println!("scale on {:<34} OK", scalar.describe());

    // 2) host, targetDP SIMD mode (TLP x ILP, VVL = 8)
    let mut simd = HostTarget::simd(8, TlpPool::default())?;
    let mut field = make_field();
    scale_on(&mut simd, &mut field, nsites, a)?;
    assert_eq!(field, expect);
    println!("scale on {:<34} OK", simd.describe());

    // 3) the accelerator analog: AOT JAX/Pallas executable via PJRT
    match XlaTarget::from_default_artifacts() {
        Ok(mut xla) => {
            let mut field = make_field();
            scale_on(&mut xla, &mut field, nsites, a)?;
            assert_eq!(field, expect);
            println!("scale on {:<34} OK", xla.describe());
        }
        Err(e) => {
            println!("xla target unavailable ({e}); run `make artifacts`")
        }
    }

    println!("\nSame application code, three targets — the paper's claim.");
    Ok(())
}
