//! End-to-end driver (experiment E6): spinodal decomposition of a binary
//! fluid on a 32^3 D3Q19 lattice, run on both the host-SIMD target and the
//! XLA (AOT JAX/Pallas) target, proving all three layers compose.
//!
//! Reports conservation (mass, phi), the growth of the order-parameter
//! variance (the physics signal of demixing), MLUPS throughput, and writes
//! observables.csv + a final phi VTK snapshot under `out/spinodal/`.
//!
//! ```text
//! cargo run --release --example lb_spinodal [-- steps]
//! ```

use targetdp::config::{Config, OutputCfg, SimulationCfg, TargetCfg};
use targetdp::coordinator::run_simulation;

fn cfg(backend: &str, steps: u64, dir: String) -> Config {
    Config {
        simulation: SimulationCfg {
            lattice: "d3q19".into(),
            lx: 32,
            ly: 32,
            lz: 32,
            steps,
            init: "spinodal".into(),
            noise: 0.1,
            seed: 7,
            radius: 8.0,
        },
        target: TargetCfg { backend: backend.into(), vvl: 8,
                            ..Default::default() },
        free_energy: Default::default(),
        output: OutputCfg { every: steps / 4, dir, vtk: true,
                            ..Default::default() },
        fault: Default::default(),
    }
}

fn main() -> targetdp::Result<()> {
    // 3-D spinodal growth needs a few hundred steps: the initial noise
    // first smooths (variance dips) before domains coarsen and the
    // variance climbs toward the two-phase value.
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    println!("=== E6: binary-fluid spinodal decomposition, 32^3, \
              {steps} steps ===\n");

    println!("--- host-simd target ---");
    let host = run_simulation(&cfg("host-simd", steps,
                                   "out/spinodal/host".into()))?;

    println!("\n--- xla target (AOT JAX/Pallas via PJRT) ---");
    let xla = match run_simulation(&cfg("xla", steps,
                                        "out/spinodal/xla".into())) {
        Ok(s) => Some(s),
        Err(e) => {
            println!("xla run skipped: {e}");
            None
        }
    };

    println!("\n=== summary ===");
    println!("{:<12} {:>10} {:>14} {:>12} {:>12}", "target", "MLUPS",
             "mass drift", "phi drift", "var growth");
    let growth =
        |s: &targetdp::coordinator::RunSummary| s.r#final.phi_variance
            / s.initial.phi_variance;
    println!("{:<12} {:>10.3} {:>14.2e} {:>12.2e} {:>11.1}x", "host-simd",
             host.mlups, host.mass_drift(), host.phi_drift(),
             growth(&host));
    if let Some(x) = &xla {
        println!("{:<12} {:>10.3} {:>14.2e} {:>12.2e} {:>11.1}x", "xla",
                 x.mlups, x.mass_drift(), x.phi_drift(), growth(x));
        let dv = (x.r#final.phi_variance - host.r#final.phi_variance).abs()
            / host.r#final.phi_variance;
        println!("\ncross-target phi-variance relative diff: {dv:.2e} \
                  (expected ~1e-12: same physics, different layers)");
        assert!(dv < 1e-6, "targets disagree");
    }
    assert!(host.mass_drift() < 1e-10);
    if steps >= 400 {
        assert!(growth(&host) > 2.0,
                "spinodal decomposition should amplify phi variance");
    } else {
        println!("(short run: variance-growth check skipped, needs >=400 \
                  steps)");
    }
    println!("\nE6 PASS — record in EXPERIMENTS.md");
    Ok(())
}
