//! Validation example: a 2-D equilibrium droplet and the Laplace law.
//!
//! A circular droplet of radius R in a binary fluid sustains a pressure
//! jump dP = sigma / R (2-D). Relaxing droplets of several radii and
//! measuring dP from the bulk pressure p0 = rho cs2 + A/2 phi^2 + 3B/4
//! phi^4 inside/outside recovers sigma, compared against the analytic
//! sigma = sqrt(-8 kappa A^3 / 9 B^2) of the symmetric functional.
//!
//! ```text
//! cargo run --release --example droplet
//! ```

use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::HostTarget;

fn pressure_jump(radius: f64, steps: u64) -> (f64, f64) {
    let model = LatticeModel::D2Q9;
    let vs = model.velset();
    let geom = Geometry::new(64, 64, 1);
    let n = geom.nsites();
    let p = FeParams::default();

    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_droplet(vs, &p, &geom, &mut f, &mut g, 32.0, 32.0, radius);

    let mut target = HostTarget::simd(8, TlpPool::default()).unwrap();
    let mut engine = LbEngine::new(&mut target, geom, model, p).unwrap();
    engine.load_state(&f, &g).unwrap();
    engine.run(steps).unwrap();
    engine.fetch_state(&mut f, &mut g).unwrap();

    // measured droplet radius from the phi = 0 contour area
    let phi_at = |s: usize| -> f64 {
        (0..vs.nvel).map(|i| g[i * n + s]).sum()
    };
    let area = (0..n).filter(|&s| phi_at(s) < 0.0).count() as f64;
    let r_eff = (area / std::f64::consts::PI).sqrt();

    // bulk pressure inside (centre) vs outside (corner), averaged 3x3
    let avg_p0 = |cx: usize, cy: usize| -> f64 {
        let mut acc = 0.0;
        for dx in 0..3 {
            for dy in 0..3 {
                let s = geom.index(cx + dx, cy + dy, 0);
                let mut rho = 0.0;
                for i in 0..vs.nvel {
                    rho += f[i * n + s];
                }
                acc += p.bulk_pressure(rho, phi_at(s));
            }
        }
        acc / 9.0
    };
    let dp = avg_p0(31, 31) - avg_p0(1, 1);
    (dp, r_eff)
}

fn main() {
    let p = FeParams::default();
    let sigma_theory = p.surface_tension();
    println!("symmetric free energy: sigma_theory = {sigma_theory:.6e}, \
              interface width xi = {:.3}\n", p.interface_width());
    println!("{:>8} {:>10} {:>14} {:>14} {:>10}", "R_init", "R_eff", "dP",
             "sigma = dP*R", "ratio");

    let mut ratios = Vec::new();
    for radius in [10.0, 14.0, 18.0] {
        let (dp, r_eff) = pressure_jump(radius, 3000);
        let sigma = dp * r_eff;
        let ratio = sigma / sigma_theory;
        ratios.push(ratio);
        println!("{radius:>8.1} {r_eff:>10.2} {dp:>14.4e} {sigma:>14.4e} \
                  {ratio:>10.3}");
    }

    let mean: f64 = ratios.iter().sum::<f64>() / ratios.len() as f64;
    println!("\nmean sigma_measured / sigma_theory = {mean:.3}");
    // Laplace law with a diffuse interface and modest radii: expect the
    // right scale and the 1/R scaling, not percent-level agreement
    assert!((0.5..2.0).contains(&mean),
            "Laplace-law surface tension should match to O(1): {mean}");
    println!("PASS: droplet pressure jump scales as sigma/R");
}
