//! Validation example: gravity-driven Poiseuille flow between bounce-back
//! walls, compared against the analytic parabolic profile.
//!
//! A single-fluid D2Q9 LB with a constant body force g_x between walls at
//! y = 0 and y = ly-1 develops u_x(y) = (g/2 nu) * y'(H - y') with
//! y' measured from the wall (mid-link bounce-back places the no-slip
//! plane half a lattice spacing inside). Demonstrates the boundary
//! substrate on top of the targetDP kernels.
//!
//! ```text
//! cargo run --release --example lb_poiseuille
//! ```

use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::boundary::{bounce_back, restore_solid, save_solid,
                             SolidMask};
use targetdp::lb::collision::collide_lattice;
use targetdp::lb::model::{d2q9, CS2};
use targetdp::lb::propagation::stream;
use targetdp::targetdp::tlp::TlpPool;

fn main() {
    let vs = d2q9();
    let geom = Geometry::new(4, 34, 1); // 32 fluid rows + 2 wall rows
    let n = geom.nsites();
    let tau = 1.0;
    let nu = CS2 * (tau - 0.5);
    let g_force = 1e-6;

    // relaxation params: pure fluid (phi = 0 everywhere)
    let p = FeParams { tau_f: tau, ..Default::default() };
    let mask = SolidMask::channel_walls_y(&geom);

    // init: rho = 1 at rest
    let mut f = vec![0.0; vs.nvel * n];
    for i in 0..vs.nvel {
        for s in 0..n {
            f[i * n + s] = vs.wv[i];
        }
    }
    let mut g = vec![0.0; vs.nvel * n]; // order parameter unused (zero)
    let grad = vec![0.0; 3 * n];
    let lap = vec![0.0; n];
    let pool = TlpPool::serial();

    let steps = 6000;
    for _ in 0..steps {
        // whole-lattice collision; solid sites excluded via save/restore
        let saved = save_solid(vs, &f, &mask, n);
        collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, n, &pool, 8,
                        false);
        restore_solid(vs, &mut f, &mask, n, &saved);
        // body force: first-moment injection on fluid sites
        for s in 0..n {
            if mask.solid[s] {
                continue;
            }
            for i in 0..vs.nvel {
                f[i * n + s] += 3.0 * vs.wv[i] * vs.cv[i][0] * g_force;
            }
        }
        let mut fs = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &f, &mut fs, &pool, 8);
        f = fs;
        bounce_back(vs, &geom, &mut f, &mask);
    }

    // measure u_x(y) on one column
    println!("{:>4} {:>14} {:>14} {:>10}", "y", "u_x measured",
             "u_x analytic", "rel err");
    let h = (geom.ly - 2) as f64; // fluid height in lattice units
    let mut max_rel: f64 = 0.0;
    for y in 1..geom.ly - 1 {
        let s = geom.index(2, y, 0);
        let mut rho = 0.0;
        let mut jx = 0.0;
        for i in 0..vs.nvel {
            rho += f[i * n + s];
            jx += vs.cv[i][0] * f[i * n + s];
        }
        let u = jx / rho;
        // wall (no-slip) plane sits half a spacing inside the solid row
        let yp = y as f64 - 0.5;
        let ua = 0.5 * g_force / nu * yp * (h - yp);
        let rel = ((u - ua) / ua).abs();
        max_rel = max_rel.max(rel);
        if y % 4 == 1 {
            println!("{y:>4} {u:>14.6e} {ua:>14.6e} {rel:>10.2e}");
        }
    }
    println!("\nmax relative error vs parabola: {max_rel:.2e}");
    assert!(max_rel < 0.02,
            "Poiseuille profile should match to ~1-2% (got {max_rel:e})");
    println!("PASS: bounce-back + collision + streaming reproduce \
              analytic channel flow");
}
