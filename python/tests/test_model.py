"""L2 model (full timestep / gradients / streaming) vs the reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def make_grids(lattice, grid, seed=0):
    rng = np.random.default_rng(seed)
    cv, wv = ref.velocity_set(lattice)
    nvel = cv.shape[0]
    f = np.abs(rng.normal(1.0, 0.02, (nvel, *grid))) * \
        wv[:, None, None, None]
    g = rng.normal(0.0, 0.02, (nvel, *grid)) * wv[:, None, None, None]
    return jnp.asarray(f), jnp.asarray(g)


@pytest.mark.parametrize("lattice,grid", [
    ("d3q19", (8, 8, 8)),
    ("d3q19", (16, 8, 4)),
    ("d2q9", (16, 16, 1)),
])
def test_full_step_matches_ref(lattice, grid):
    f, g = make_grids(lattice, grid)
    p = ref.FreeEnergyParams()
    fr, gr = ref.timestep(f, g, p, lattice)
    fm, gm = model.full_step(f, g, lattice=lattice, vvl_block=64, params=p)
    assert_allclose(np.asarray(fm), np.asarray(fr), rtol=0, atol=1e-13)
    assert_allclose(np.asarray(gm), np.asarray(gr), rtol=0, atol=1e-13)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       steps=st.integers(min_value=1, max_value=5))
def test_multi_step_conservation(seed, steps):
    """Mass and order parameter conserved over repeated full steps."""
    f, g = make_grids("d3q19", (8, 8, 8), seed)
    p = ref.FreeEnergyParams()
    m0, p0 = float(jnp.sum(f)), float(jnp.sum(g))
    for _ in range(steps):
        f, g = model.full_step(f, g, lattice="d3q19", vvl_block=64, params=p)
    assert_allclose(float(jnp.sum(f)), m0, rtol=1e-12)
    assert_allclose(float(jnp.sum(g)), p0, rtol=0, atol=1e-10)


def test_gradient_matches_manual():
    """Central differences on a periodic sinusoid."""
    L = 32
    x = np.arange(L)
    phi = np.sin(2 * np.pi * x / L)
    phi_grid = jnp.asarray(np.broadcast_to(phi[:, None, None], (L, 8, 4)))
    grad, lap = model.gradient_step(phi_grid)
    # d/dx sin(kx) with the 2nd-order stencil -> sin(k)/1 * cos factor
    k = 2 * np.pi / L
    expect_gx = np.cos(k * x) * np.sin(k)  # discrete derivative
    assert_allclose(np.asarray(grad[0][:, 0, 0]), expect_gx,
                    rtol=0, atol=1e-12)
    assert_allclose(np.asarray(grad[1]), 0.0, atol=1e-12)
    assert_allclose(np.asarray(grad[2]), 0.0, atol=1e-12)
    expect_lap = (2 * np.cos(k) - 2) * np.sin(k * x)
    assert_allclose(np.asarray(lap[:, 0, 0]), expect_lap, rtol=0, atol=1e-12)


def test_gradient_constant_field_zero():
    phi = jnp.full((8, 8, 8), 0.7)
    grad, lap = model.gradient_step(phi)
    assert_allclose(np.asarray(grad), 0.0, atol=1e-14)
    assert_allclose(np.asarray(lap), 0.0, atol=1e-14)


def test_stream_permutes_sites():
    """Streaming is a pure permutation: sorted values invariant per velocity."""
    rng = np.random.default_rng(2)
    cv, _ = ref.velocity_set("d3q19")
    h = jnp.asarray(rng.normal(size=(19, 6, 5, 4)))
    hs = ref.stream(h, cv)
    for i in range(19):
        assert_allclose(np.sort(np.asarray(hs[i]).ravel()),
                        np.sort(np.asarray(h[i]).ravel()), rtol=0, atol=0)


def test_stream_roundtrip():
    """Streaming with c then with -c is the identity (index parity pairs)."""
    rng = np.random.default_rng(4)
    cv, _ = ref.velocity_set("d3q19")
    h = jnp.asarray(rng.normal(size=(19, 4, 4, 4)))
    hs = ref.stream(ref.stream(h, cv), -cv)
    assert_allclose(np.asarray(hs), np.asarray(h), rtol=0, atol=0)


def test_multi_step_equals_repeated_full_step():
    f, g = make_grids("d3q19", (8, 8, 8), seed=5)
    p = ref.FreeEnergyParams()
    fm, gm = model.multi_step(f, g, steps=4, lattice="d3q19",
                              vvl_block=64, params=p)
    fr, gr = f, g
    for _ in range(4):
        fr, gr = model.full_step(fr, gr, lattice="d3q19", vvl_block=64,
                                 params=p)
    assert_allclose(np.asarray(fm), np.asarray(fr), rtol=0, atol=1e-13)
    assert_allclose(np.asarray(gm), np.asarray(gr), rtol=0, atol=1e-13)


def test_uniform_state_is_steady():
    """A uniform zero-velocity equilibrium is an exact fixed point of the
    full step (collision + streaming)."""
    grid = (8, 8, 8)
    n = int(np.prod(grid))
    rho = jnp.full((n,), 1.0)
    phi = jnp.full((n,), 0.4)
    u = jnp.zeros((3, n))
    p = ref.FreeEnergyParams()
    f, g = ref.equilibrium_init(rho, u, phi, p, "d3q19")
    f = f.reshape(19, *grid)
    g = g.reshape(19, *grid)
    f2, g2 = model.full_step(f, g, lattice="d3q19", vvl_block=64, params=p)
    assert_allclose(np.asarray(f2), np.asarray(f), rtol=0, atol=1e-14)
    assert_allclose(np.asarray(g2), np.asarray(g), rtol=0, atol=1e-14)
