"""AOT path: the HLO-text interchange must preserve what the Rust runtime
needs — in particular large array constants (the per-velocity projection
tables) and parser-compatible attributes."""

import json
import pathlib

import jax
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def lower_collision(n=512, block=128):
    shapes = [(19, n), (19, n), (3, n), (n,)]
    fn = lambda f, g, gr, lp: model.collision_step(  # noqa: E731
        f, g, gr, lp, vvl_block=block)
    return jax.jit(fn).lower(*map(aot.spec, shapes))


def test_hlo_text_keeps_large_constants():
    """Default printing elides f64 tables as `constant({...})`, which the
    xla_extension 0.5.1 text parser silently zero-fills — the bug class
    that broke cross-layer parity. Must never reappear."""
    text = aot.to_hlo_text(lower_collision())
    assert "constant({...})" not in text
    # the D3Q19 weight 1/36 appears verbatim in some form
    assert "0.027777" in text or "1/36" in text


def test_hlo_text_has_no_new_metadata_attrs():
    """xla_extension 0.5.1 rejects source_end_line/source_end_column."""
    text = aot.to_hlo_text(lower_collision())
    assert "source_end_line" not in text
    assert "metadata=" not in text


def test_artifact_names_and_entries():
    p = ref.FreeEnergyParams()
    art = aot.build_collision("d3q19", 512, 128, p)
    assert art.name == "collision_d3q19_n512_vvl128"
    entry = art.manifest_entry()
    assert entry["kind"] == "collision"
    assert entry["n_sites"] == 512
    assert entry["params"]["tau_g"] == p.tau_g
    assert entry["inputs"][0]["shape"] == [19, 512]
    assert entry["outputs"] == entry["inputs"][:2]


def test_multi_step_entry_records_steps():
    p = ref.FreeEnergyParams()
    art = aot.build_multi_step("d2q9", (8, 8, 1), 3, 32, p)
    e = art.manifest_entry()
    assert e["steps"] == 3
    assert e["grid"] == [8, 8, 1]
    assert e["kind"] == "multi_step"


def test_shipped_manifest_consistent():
    """If artifacts/ exists, every manifest entry must point at a real file
    whose text parses as HLO-ish content."""
    out = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    if not (out / "manifest.json").exists():
        pytest.skip("run `make artifacts` first")
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest) >= 6
    kinds = {m["kind"] for m in manifest}
    assert {"collision", "full_step", "multi_step", "gradient",
            "scale"} <= kinds
    for m in manifest:
        path = out / m["file"]
        assert path.exists(), m["file"]
        head = path.read_text()[:200]
        assert head.startswith("HloModule"), m["file"]
        # elided constants must never ship
        assert "constant({...})" not in path.read_text(), m["file"]


def test_quick_flag_subset():
    quick = {a.name for a in aot.default_artifacts(quick=True)}
    full = {a.name for a in aot.default_artifacts(quick=False)}
    assert quick < full
    assert any("n32768" in n for n in full - quick)


def test_spec_is_f64():
    s = aot.spec((3, 4))
    assert s.dtype == np.float64
    assert s.shape == (3, 4)
