"""Pallas collision kernel vs the pure-jnp oracle — the core correctness
signal of the stack (system prompt: hypothesis sweeps shapes/dtypes and
assert_allclose against ref)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import collision as col
from compile.kernels import ref

LATTICES = ["d3q19", "d2q9"]


def make_state(lattice, n, seed=0, dtype=np.float64):
    """Random near-equilibrium state: positive rho, small phi/u/gradients."""
    rng = np.random.default_rng(seed)
    cv, wv = ref.velocity_set(lattice)
    nvel = cv.shape[0]
    f = np.abs(rng.normal(1.0, 0.05, (nvel, n))) * wv[:, None]
    g = rng.normal(0.0, 0.05, (nvel, n)) * wv[:, None]
    grad = rng.normal(0.0, 0.01, (3, n))
    if ref.ndim_of(lattice) == 2:
        grad[2] = 0.0
    lap = rng.normal(0.0, 0.01, n)
    return (jnp.asarray(x, dtype) for x in (f, g, grad, lap))


@pytest.mark.parametrize("lattice", LATTICES)
@pytest.mark.parametrize("vvl_block", [32, 128, 256])
def test_kernel_matches_ref(lattice, vvl_block):
    n = 4 * vvl_block
    f, g, grad, lap = make_state(lattice, n)
    p = ref.FreeEnergyParams()
    fr, gr = ref.collide(f, g, grad, lap, p, lattice)
    fk, gk = col.collide(f, g, grad, lap, lattice=lattice,
                         vvl_block=vvl_block, params=p)
    assert_allclose(np.asarray(fk), np.asarray(fr), rtol=0, atol=1e-13)
    assert_allclose(np.asarray(gk), np.asarray(gr), rtol=0, atol=1e-13)


@settings(max_examples=25, deadline=None)
@given(
    lattice=st.sampled_from(LATTICES),
    chunks=st.integers(min_value=1, max_value=8),
    log_block=st.integers(min_value=3, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_property(lattice, chunks, log_block, seed):
    """Hypothesis sweep: any (n, vvl_block) with n % vvl_block == 0."""
    vvl_block = 2 ** log_block
    n = chunks * vvl_block
    f, g, grad, lap = make_state(lattice, n, seed)
    p = ref.FreeEnergyParams()
    fr, gr = ref.collide(f, g, grad, lap, p, lattice)
    fk, gk = col.collide(f, g, grad, lap, lattice=lattice,
                         vvl_block=vvl_block, params=p)
    assert_allclose(np.asarray(fk), np.asarray(fr), rtol=0, atol=1e-13)
    assert_allclose(np.asarray(gk), np.asarray(gr), rtol=0, atol=1e-13)


@settings(max_examples=15, deadline=None)
@given(
    lattice=st.sampled_from(LATTICES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    a=st.floats(min_value=-0.2, max_value=-0.001),
    kappa=st.floats(min_value=0.001, max_value=0.2),
    tau_f=st.floats(min_value=0.55, max_value=2.5),
)
def test_collision_conserves(lattice, seed, a, kappa, tau_f):
    """Mass, momentum and order parameter are invariants of collision for
    ANY admissible free-energy parameters (paper's physics substrate)."""
    n = 256
    f, g, grad, lap = make_state(lattice, n, seed)
    p = ref.FreeEnergyParams(a=a, b=-a, kappa=kappa, tau_f=tau_f)
    fk, gk = col.collide(f, g, grad, lap, lattice=lattice,
                         vvl_block=128, params=p)
    cv, _ = ref.velocity_set(lattice)
    assert_allclose(float(jnp.sum(fk)), float(jnp.sum(f)), rtol=1e-12)
    assert_allclose(float(jnp.sum(gk)), float(jnp.sum(g)), rtol=0, atol=1e-11)
    mom0 = np.einsum("ia,in->a", cv, np.asarray(f))
    mom1 = np.einsum("ia,in->a", cv, np.asarray(fk))
    assert_allclose(mom1, mom0, rtol=0, atol=1e-11)


@pytest.mark.parametrize("lattice", LATTICES)
def test_equilibrium_is_fixed_point(lattice):
    """collide(equilibrium state with zero gradients) == identity."""
    n = 128
    rng = np.random.default_rng(3)
    rho = jnp.asarray(np.abs(rng.normal(1.0, 0.02, n)))
    phi = jnp.asarray(rng.normal(0.0, 0.3, n))
    u = jnp.asarray(rng.normal(0.0, 0.01, (3, n)))
    if ref.ndim_of(lattice) == 2:
        u = u.at[2].set(0.0)
    p = ref.FreeEnergyParams()
    f, g = ref.equilibrium_init(rho, u, phi, p, lattice)
    zero3 = jnp.zeros((3, n))
    zero1 = jnp.zeros(n)
    fk, gk = col.collide(f, g, zero3, zero1, lattice=lattice,
                         vvl_block=128, params=p)
    assert_allclose(np.asarray(fk), np.asarray(f), rtol=0, atol=1e-13)
    assert_allclose(np.asarray(gk), np.asarray(g), rtol=0, atol=1e-13)


@pytest.mark.parametrize("lattice", LATTICES)
def test_equilibrium_moments_exact(lattice):
    """The moment projection reproduces its target moments exactly."""
    n = 64
    rng = np.random.default_rng(7)
    cv, wv = ref.velocity_set(lattice)
    eye_d = ref.lattice_eye(lattice)
    a = jnp.asarray(np.abs(rng.normal(1.0, 0.1, n)))
    b = jnp.asarray(rng.normal(0.0, 0.05, (3, n)) * eye_d.diagonal()[:, None])
    s_raw = rng.normal(0.0, 0.05, (3, 3, n))
    s_raw = 0.5 * (s_raw + s_raw.transpose(1, 0, 2))
    # mask S to the active dimensions so 2-D sets stay consistent
    s = jnp.asarray(s_raw * eye_d.diagonal()[:, None, None]
                    * eye_d.diagonal()[None, :, None])
    h = ref.equilibrium(wv, cv, a, b, s, eye_d)
    assert_allclose(np.asarray(jnp.sum(h, axis=0)), np.asarray(a),
                    rtol=0, atol=1e-13)
    mom1 = np.einsum("ia,in->an", cv, np.asarray(h))
    assert_allclose(mom1, np.asarray(b), rtol=0, atol=1e-13)
    # second moment = a/3 * I_d + S
    mom2 = np.einsum("ia,ib,in->abn", cv, cv, np.asarray(h))
    want = (np.asarray(a)[None, None, :] / 3.0) * eye_d[:, :, None] + \
        np.asarray(s)
    assert_allclose(mom2, want, rtol=0, atol=1e-12)


def test_vvl_block_invariance():
    """The result must not depend on the VVL partitioning (paper: VVL is a
    pure performance knob)."""
    n = 2048
    f, g, grad, lap = make_state("d3q19", n, seed=11)
    p = ref.FreeEnergyParams()
    outs = [col.collide(f, g, grad, lap, lattice="d3q19",
                        vvl_block=b, params=p) for b in (32, 256, 2048)]
    for fk, gk in outs[1:]:
        assert_allclose(np.asarray(fk), np.asarray(outs[0][0]),
                        rtol=0, atol=1e-14)
        assert_allclose(np.asarray(gk), np.asarray(outs[0][1]),
                        rtol=0, atol=1e-14)


def test_kernel_rejects_misaligned_n():
    f, g, grad, lap = make_state("d3q19", 100)
    with pytest.raises(ValueError, match="multiple of vvl_block"):
        col.collide(f, g, grad, lap, lattice="d3q19", vvl_block=64)


def test_scale_kernel():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 1024)))
    y = col.scale(x, a=2.5, vvl_block=128)
    assert_allclose(np.asarray(y), 2.5 * np.asarray(x), rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(a=st.one_of(st.just(0.0),
                   st.floats(min_value=1e-3, max_value=10.0),
                   st.floats(min_value=-10.0, max_value=-1e-3)),
       log_block=st.integers(min_value=4, max_value=10))
def test_scale_kernel_property(a, log_block):
    # |a| bounded away from 0: XLA flushes denormal products to zero.
    blk = 2 ** log_block
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(3, 4 * blk)))
    y = col.scale(x, a=a, vvl_block=blk)
    assert_allclose(np.asarray(y), a * np.asarray(x), rtol=1e-15, atol=0)
