"""Layer-1 Pallas kernels: binary-fluid LB collision + the paper's scale demo.

The paper exposes lattice parallelism as TLP x ILP by strip-mining the site
loop into chunks of a virtual vector length (VVL). The Pallas analog
(DESIGN.md section 3): the grid iterates over site *chunks* and the BlockSpec
block width ``vvl_block`` is the VVL — each grid step owns a
``(nvel, vvl_block)`` SoA slab resident in VMEM and performs the full
collision for those sites. Tuning ``vvl_block`` trades grid steps against
per-step vector work, exactly the paper's "fewer blocks x more ILP" knob.

Kernels MUST be lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).

Free-energy parameters are baked into the kernel at trace time — the
``TARGET_CONST`` / ``copyConstantDoubleToTarget`` analog: constants live
"as close to the registers as possible" (folded into the HLO).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

# Unique symmetric-tensor component order used throughout: xx xy xz yy yz zz
SYM6 = [(0, 0), (0, 1), (0, 2), (1, 1), (1, 2), (2, 2)]
# Off-diagonal components appear twice in S : Q contractions.
SYM6_MULT = np.array([1.0, 2.0, 2.0, 1.0, 2.0, 1.0])


def _projection_tables(lattice: str):
    """Per-velocity constants for the moment-projection equilibrium.

    Returns (c (nvel,3), w (nvel,), q6 (nvel,6)) where
    q6[i,k] = multiplicity_k * (c_i c_i - I_d/3)_{ab(k)} — so that
    sum_ab Q_iab S_ab == q6[i] . s6 for a symmetric S packed as s6.
    I_d is the dimension-embedded identity (ref.lattice_eye): for D2Q9 the
    zz/xz/yz rows vanish, which keeps mass/phi exactly conserved.
    """
    cv, wv = ref.velocity_set(lattice)
    eye_d = ref.lattice_eye(lattice)
    nvel = cv.shape[0]
    q6 = np.empty((nvel, 6))
    for k, (a, b) in enumerate(SYM6):
        q = cv[:, a] * cv[:, b] - eye_d[a, b] / 3.0
        q6[:, k] = SYM6_MULT[k] * q
    return cv, wv, q6


def _collision_body(f, g, grad, lap, cv, wv, q6, p: ref.FreeEnergyParams):
    """Collision math over one SoA slab. f,g: (nvel,B); grad: (3,B); lap: (B,).

    Shared between the Pallas kernel body and the jnp fallback so the two
    cannot drift.
    """
    dt = f.dtype
    c = jnp.asarray(cv, dt)          # (nvel, 3)
    w = jnp.asarray(wv, dt)          # (nvel,)
    q = jnp.asarray(q6, dt)          # (nvel, 6)

    # Moments (the per-site reductions the paper's kernel performs).
    rho = jnp.sum(f, axis=0)                     # (B,)
    rho_u = jnp.einsum("ia,ib->ab", c, f)        # (3, B)
    phi = jnp.sum(g, axis=0)
    phi_u_over = jnp.einsum("ia,ib->ab", c, g)   # unused: g momentum not needed
    del phi_u_over
    u = rho_u / rho                              # (3, B)

    # Free-energy sector (constants baked).
    phi2 = phi * phi
    mu = p.a * phi + p.b * phi * phi2 - p.kappa * lap
    p0 = rho * ref.CS2 + 0.5 * p.a * phi2 + 0.75 * p.b * phi2 * phi2
    gsq = grad[0] * grad[0] + grad[1] * grad[1] + grad[2] * grad[2]
    iso = p0 - p.kappa * phi * lap - 0.5 * p.kappa * gsq

    # Symmetric tensors packed as 6 components (xx xy xz yy yz zz).
    def sym6(diag, off_scale_vec, uu_scale):
        """diag: (B,) isotropic part; plus kappa grad grad / scale * u u."""
        comps = []
        for k, (a, b) in enumerate(SYM6):
            val = uu_scale * u[a] * u[b] + off_scale_vec * grad[a] * grad[b]
            if a == b:
                val = val + diag
            comps.append(val)
        return jnp.stack(comps, axis=0)          # (6, B)

    s_f6 = sym6(iso - rho * ref.CS2, p.kappa * jnp.ones_like(rho), rho)
    s_g6 = sym6(p.gamma * mu - phi * ref.CS2, jnp.zeros_like(rho), phi)

    cb_f = jnp.einsum("ia,ab->ib", c, rho_u)     # (nvel, B)
    cb_g = jnp.einsum("ia,ab->ib", c, phi[None, :] * u)
    qs_f = jnp.einsum("ik,kb->ib", q, s_f6)
    qs_g = jnp.einsum("ik,kb->ib", q, s_g6)

    feq = w[:, None] * (rho[None, :] + 3.0 * cb_f + 4.5 * qs_f)
    geq = w[:, None] * (phi[None, :] + 3.0 * cb_g + 4.5 * qs_g)

    f_out = f - (f - feq) / p.tau_f
    g_out = g - (g - geq) / p.tau_g
    return f_out, g_out


def _collision_kernel(f_ref, g_ref, grad_ref, lap_ref, c_ref, w_ref, q_ref,
                      fo_ref, go_ref, *, params):
    # c/w/q are the small per-velocity constant tables, passed as operands —
    # the copyConstant*ToTarget analog (Pallas forbids captured array consts).
    f = f_ref[...]
    g = g_ref[...]
    grad = grad_ref[...]
    lap = lap_ref[...][0]  # (1, B) block -> (B,)
    f_out, g_out = _collision_body(
        f, g, grad, lap, c_ref[...], w_ref[...][:, 0], q_ref[...], params)
    fo_ref[...] = f_out
    go_ref[...] = g_out


@functools.partial(jax.jit, static_argnames=("lattice", "vvl_block", "params"))
def collide(f, g, grad_phi, lap_phi, *, lattice: str = "d3q19",
            vvl_block: int = 256,
            params: ref.FreeEnergyParams = ref.FreeEnergyParams()):
    """Pallas binary collision. f,g: (nvel,N); grad: (3,N); lap: (N,).

    N must be a multiple of ``vvl_block`` (the lattice layer pads; DESIGN §3).
    """
    cv, wv, q6 = _projection_tables(lattice)
    nvel = cv.shape[0]
    n = f.shape[1]
    if n % vvl_block:
        raise ValueError(f"n={n} not a multiple of vvl_block={vvl_block}")
    grid = (n // vvl_block,)

    slab = lambda rows: pl.BlockSpec((rows, vvl_block), lambda i: (0, i))
    const = lambda cols: pl.BlockSpec((nvel, cols), lambda i: (0, 0))
    dt = f.dtype
    return pl.pallas_call(
        functools.partial(_collision_kernel, params=params),
        grid=grid,
        in_specs=[slab(nvel), slab(nvel), slab(3), slab(1),
                  const(3), const(1), const(6)],
        out_specs=[slab(nvel), slab(nvel)],
        out_shape=[
            jax.ShapeDtypeStruct((nvel, n), dt),
            jax.ShapeDtypeStruct((nvel, n), dt),
        ],
        interpret=True,
    )(f, g, grad_phi, lap_phi[None, :],
      jnp.asarray(cv, dt), jnp.asarray(wv, dt)[:, None], jnp.asarray(q6, dt))


# ---------------------------------------------------------------------------
# The paper's section III running example: scale a 3-vector field by a const
# ---------------------------------------------------------------------------

def _scale_kernel(x_ref, o_ref, *, a):
    o_ref[...] = a * x_ref[...]


@functools.partial(jax.jit, static_argnames=("a", "vvl_block"))
def scale(field, *, a: float = 1.5, vvl_block: int = 256):
    """field: (3, N) SoA 3-vector field; returns a*field via Pallas."""
    ndim, n = field.shape
    if n % vvl_block:
        raise ValueError(f"n={n} not a multiple of vvl_block={vvl_block}")
    return pl.pallas_call(
        functools.partial(_scale_kernel, a=a),
        grid=(n // vvl_block,),
        in_specs=[pl.BlockSpec((ndim, vvl_block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((ndim, vvl_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((ndim, n), field.dtype),
        interpret=True,
    )(field)
