"""Pure-jnp reference oracle for the binary-fluid LB collision.

This is the CORE correctness signal of the stack: the Pallas kernel
(kernels/collision.py), the JAX model (model.py) and every Rust kernel
(targetdp host targets, baseline) must agree with these functions
(allclose at f64 tolerances).

Physics (DESIGN.md section 5) — Ludwig/Kendon-style binary fluid:
  rho  = sum_i f_i,   rho*u = sum_i f_i c_i,   phi = sum_i g_i
  mu   = A phi + B phi^3 - kappa lap(phi)
  p0   = rho cs2 + A/2 phi^2 + 3B/4 phi^4
  Pth  = (p0 - kappa phi lap(phi) - kappa/2 |grad phi|^2) I
         + kappa grad(phi) x grad(phi)
  equilibria via moment projection
      h_i^eq = w_i [ a + 3 b.c_i + 9/2 S : (c_i c_i - I/3) ]
  f:  a=rho, b=rho u, S = Pth + rho u u - rho cs2 I
  g:  a=phi, b=phi u, S = (Gamma mu - phi cs2) I + phi u u
  BGK h <- h - (h - h^eq)/tau
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

CS2 = 1.0 / 3.0


# ---------------------------------------------------------------------------
# Velocity sets (SoA-friendly: arrays of shape (nvel, ndim) / (nvel,))
# ---------------------------------------------------------------------------

def d3q19_velocities() -> np.ndarray:
    """The 19 D3Q19 lattice vectors, rest vector first (Ludwig ordering)."""
    c = [(0, 0, 0)]
    # 6 face neighbours
    c += [(1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1)]
    # 12 edge neighbours
    c += [
        (1, 1, 0), (-1, -1, 0), (1, -1, 0), (-1, 1, 0),
        (1, 0, 1), (-1, 0, -1), (1, 0, -1), (-1, 0, 1),
        (0, 1, 1), (0, -1, -1), (0, 1, -1), (0, -1, 1),
    ]
    return np.array(c, dtype=np.float64)


def d3q19_weights() -> np.ndarray:
    w = np.empty(19, dtype=np.float64)
    w[0] = 1.0 / 3.0
    w[1:7] = 1.0 / 18.0
    w[7:19] = 1.0 / 36.0
    return w


def d2q9_velocities() -> np.ndarray:
    """D2Q9 embedded in 3-D (z component zero) so the same kernel applies."""
    c = [(0, 0, 0),
         (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0),
         (1, 1, 0), (-1, -1, 0), (1, -1, 0), (-1, 1, 0)]
    return np.array(c, dtype=np.float64)


def d2q9_weights() -> np.ndarray:
    w = np.empty(9, dtype=np.float64)
    w[0] = 4.0 / 9.0
    w[1:5] = 1.0 / 9.0
    w[5:9] = 1.0 / 36.0
    return w


def velocity_set(name: str):
    """Returns (c (nvel,3) float64, w (nvel,) float64)."""
    if name == "d3q19":
        return d3q19_velocities(), d3q19_weights()
    if name == "d2q9":
        return d2q9_velocities(), d2q9_weights()
    raise ValueError(f"unknown velocity set {name!r}")


def ndim_of(name: str) -> int:
    return {"d3q19": 3, "d2q9": 2}[name]


def lattice_eye(name: str) -> np.ndarray:
    """I_d embedded in 3x3: diag(1,1,1) for D3Q19, diag(1,1,0) for D2Q9.

    The moment-projection equilibrium needs (c c - I_d/3): with the full
    3-D identity a D2Q9 set would violate Sum w_i (c c - I/3) = 0 on the
    zz component and break mass/phi conservation.
    """
    e = np.zeros((3, 3))
    d = ndim_of(name)
    e[:d, :d] = np.eye(d)
    return e


@dataclasses.dataclass(frozen=True)
class FreeEnergyParams:
    """Symmetric (phi^4) binary free energy + mobility + relaxation times."""

    a: float = -0.0625      # A < 0: two-phase region
    b: float = 0.0625       # B > 0
    kappa: float = 0.04     # interfacial penalty
    gamma: float = 1.0      # order-parameter mobility prefactor Gamma
    tau_f: float = 1.0      # fluid relaxation time
    tau_g: float = 0.8      # order-parameter relaxation time

    def as_array(self) -> np.ndarray:
        """Pack in the order the kernels/artifacts expect (see collision.py)."""
        return np.array(
            [self.a, self.b, self.kappa, self.gamma, self.tau_f, self.tau_g],
            dtype=np.float64,
        )


# ---------------------------------------------------------------------------
# Reference collision (SoA: f (nvel, n), g (nvel, n), grad (3, n), lap (n,))
# ---------------------------------------------------------------------------

def moments(h, cv):
    """Zeroth and first moments of a distribution. h: (nvel, n)."""
    dens = jnp.sum(h, axis=0)
    mom = jnp.einsum("ia,in->an", jnp.asarray(cv, dtype=h.dtype), h)
    return dens, mom


def equilibrium(wv, cv, a, b_vec, s_tensor, eye_d=None):
    """h_i = w_i [a + 3 b.c_i + 9/2 S : (c_i c_i - I_d/3)].

    Shapes: a (n,), b_vec (3, n), s_tensor (3, 3, n) -> (nvel, n).
    eye_d is the dimension-embedded identity (lattice_eye); default 3-D.
    """
    wv = jnp.asarray(wv, dtype=a.dtype)
    cv = jnp.asarray(cv, dtype=a.dtype)
    if eye_d is None:
        eye_d = np.eye(3)
    q = (
        cv[:, :, None] * cv[:, None, :]
        - jnp.asarray(eye_d, dtype=cv.dtype)[None, :, :] / 3.0
    )  # (nvel, 3, 3)
    term1 = a[None, :]
    term2 = 3.0 * jnp.einsum("ia,an->in", cv, b_vec)
    term3 = 4.5 * jnp.einsum("iab,abn->in", q, s_tensor)
    return wv[:, None] * (term1 + term2 + term3)


def chemical_potential(phi, lap_phi, p: FreeEnergyParams):
    return p.a * phi + p.b * phi**3 - p.kappa * lap_phi


def bulk_pressure(rho, phi, p: FreeEnergyParams):
    return rho * CS2 + 0.5 * p.a * phi**2 + 0.75 * p.b * phi**4


def thermodynamic_pressure_tensor(rho, phi, grad_phi, lap_phi,
                                  p: FreeEnergyParams, eye_d=None):
    """Pth, shape (3, 3, n)."""
    p0 = bulk_pressure(rho, phi, p)
    iso = p0 - p.kappa * phi * lap_phi - 0.5 * p.kappa * jnp.sum(
        grad_phi**2, axis=0)
    if eye_d is None:
        eye_d = np.eye(3)
    eye = jnp.asarray(eye_d, dtype=grad_phi.dtype)
    return (
        iso[None, None, :] * eye[:, :, None]
        + p.kappa * grad_phi[:, None, :] * grad_phi[None, :, :]
    )


def collide(f, g, grad_phi, lap_phi, params: FreeEnergyParams,
            lattice: str = "d3q19"):
    """One BGK binary-fluid collision over n sites. All SoA, float64.

    f, g: (nvel, n); grad_phi: (3, n); lap_phi: (n,).
    Returns (f', g') post-collision (pre-streaming).
    """
    cv, wv = velocity_set(lattice)
    eye_d = lattice_eye(lattice)
    rho, rho_u = moments(f, cv)
    phi, _ = moments(g, cv)
    u = rho_u / rho
    uu = u[:, None, :] * u[None, :, :]
    eye = jnp.asarray(eye_d, dtype=f.dtype)

    pth = thermodynamic_pressure_tensor(rho, phi, grad_phi, lap_phi, params,
                                        eye_d)
    s_f = (pth + rho[None, None, :] * uu
           - (rho * CS2)[None, None, :] * eye[:, :, None])
    feq = equilibrium(wv, cv, rho, rho_u, s_f, eye_d)

    mu = chemical_potential(phi, lap_phi, params)
    s_g = ((params.gamma * mu - phi * CS2)[None, None, :] * eye[:, :, None]
           + phi[None, None, :] * uu)
    geq = equilibrium(wv, cv, phi, phi[None, :] * u, s_g, eye_d)

    f_out = f - (f - feq) / params.tau_f
    g_out = g - (g - geq) / params.tau_g
    return f_out, g_out


# ---------------------------------------------------------------------------
# Reference field ops on a full periodic lattice, grid shape (Lx, Ly, Lz)
# ---------------------------------------------------------------------------

def gradient_fd(phi_grid):
    """Central-difference grad (3, ...) and laplacian of a periodic field."""
    grads = []
    lap = -6.0 * phi_grid
    for axis in range(3):
        up = jnp.roll(phi_grid, -1, axis=axis)
        dn = jnp.roll(phi_grid, 1, axis=axis)
        grads.append(0.5 * (up - dn))
        lap = lap + up + dn
    return jnp.stack(grads, axis=0), lap


def stream(h_grid, cv):
    """Push-streaming on a periodic grid. h_grid: (nvel, Lx, Ly, Lz)."""
    cv = np.asarray(cv, dtype=np.int64)
    out = []
    for i in range(h_grid.shape[0]):
        hi = h_grid[i]
        for axis in range(3):
            s = int(cv[i, axis])
            if s:
                hi = jnp.roll(hi, s, axis=axis)
        out.append(hi)
    return jnp.stack(out, axis=0)


def timestep(f_grid, g_grid, params: FreeEnergyParams, lattice="d3q19"):
    """Full reference LB step: moments -> gradients -> collide -> stream.

    f_grid, g_grid: (nvel, Lx, Ly, Lz) periodic.
    """
    cv, _ = velocity_set(lattice)
    shape = f_grid.shape
    nvel, grid = shape[0], shape[1:]
    phi_grid = jnp.sum(g_grid, axis=0)
    grad_grid, lap_grid = gradient_fd(phi_grid)

    n = int(np.prod(grid))
    f = f_grid.reshape(nvel, n)
    g = g_grid.reshape(nvel, n)
    grad = grad_grid.reshape(3, n)
    lap = lap_grid.reshape(n)
    f2, g2 = collide(f, g, grad, lap, params, lattice)
    f2 = f2.reshape(shape)
    g2 = g2.reshape(shape)
    return stream(f2, cv), stream(g2, cv)


def equilibrium_init(rho, u, phi, params: FreeEnergyParams, lattice="d3q19"):
    """Initial (f, g) at local equilibrium with zero phi gradients.

    rho, phi: (n,); u: (3, n). Returns f, g of shape (nvel, n).
    """
    cv, wv = velocity_set(lattice)
    eye_d = lattice_eye(lattice)
    eye = jnp.asarray(eye_d, dtype=rho.dtype)
    uu = u[:, None, :] * u[None, :, :]
    zero_grad = jnp.zeros_like(u)
    zero_lap = jnp.zeros_like(rho)
    pth = thermodynamic_pressure_tensor(rho, phi, zero_grad, zero_lap, params,
                                        eye_d)
    s_f = (pth + rho[None, None, :] * uu
           - (rho * CS2)[None, None, :] * eye[:, :, None])
    f = equilibrium(wv, cv, rho, rho[None, :] * u, s_f, eye_d)
    mu = chemical_potential(phi, zero_lap, params)
    s_g = ((params.gamma * mu - phi * CS2)[None, None, :] * eye[:, :, None]
           + phi[None, None, :] * uu)
    g = equilibrium(wv, cv, phi, phi[None, :] * u, s_g, eye_d)
    return f, g
