"""Layer-2 JAX model: the lattice-Boltzmann compute graph around the L1
Pallas collision kernel.

Exposed entry points (each AOT-lowered to an HLO artifact by aot.py and run
from the Rust runtime; Python never executes on the request path):

* ``collision_step`` — the paper's Figure-1 benchmark kernel: binary-fluid
  BGK collision over N sites (SoA). Pure Pallas, no neighbour access.
* ``gradient_step``  — central-difference grad/laplacian of the order
  parameter on the periodic grid (roll-based; XLA fuses the rolls).
* ``full_step``      — one complete LB timestep: phi moments -> gradients ->
  Pallas collision -> streaming. Used by the end-to-end driver so the whole
  "device side" of a timestep is a single fused executable (no host
  round-trips mid-step, DESIGN.md section 9).

All arrays are float64 (jax_enable_x64 is set in aot.py / tests).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernels import collision as kernels
from .kernels import ref


def collision_step(f, g, grad_phi, lap_phi, *, lattice="d3q19",
                   vvl_block=256, params=ref.FreeEnergyParams()):
    """Benchmark entry point: f,g (nvel,N), grad (3,N), lap (N,)."""
    return kernels.collide(f, g, grad_phi, lap_phi, lattice=lattice,
                           vvl_block=vvl_block, params=params)


def gradient_step(phi_grid):
    """grad (3,Lx,Ly,Lz) and laplacian (Lx,Ly,Lz) of a periodic field."""
    return ref.gradient_fd(phi_grid)


def _stream(h_grid, cv):
    """Push-streaming via rolls; unrolled over the (static) velocity set."""
    cv = np.asarray(cv, dtype=np.int64)
    out = []
    for i in range(h_grid.shape[0]):
        hi = h_grid[i]
        for axis in range(3):
            s = int(cv[i, axis])
            if s:
                hi = jnp.roll(hi, s, axis=axis)
        out.append(hi)
    return jnp.stack(out, axis=0)


def full_step(f_grid, g_grid, *, lattice="d3q19", vvl_block=256,
              params=ref.FreeEnergyParams()):
    """One LB timestep on the periodic grid. f,g: (nvel, Lx, Ly, Lz)."""
    cv, _ = ref.velocity_set(lattice)
    shape = f_grid.shape
    nvel = shape[0]
    n = int(np.prod(shape[1:]))

    phi_grid = jnp.sum(g_grid, axis=0)
    grad_grid, lap_grid = ref.gradient_fd(phi_grid)

    f2, g2 = kernels.collide(
        f_grid.reshape(nvel, n), g_grid.reshape(nvel, n),
        grad_grid.reshape(3, n), lap_grid.reshape(n),
        lattice=lattice, vvl_block=vvl_block, params=params)

    f2 = _stream(f2.reshape(shape), cv)
    g2 = _stream(g2.reshape(shape), cv)
    return f2, g2


def multi_step(f_grid, g_grid, *, steps=10, lattice="d3q19", vvl_block=256,
               params=ref.FreeEnergyParams()):
    """``steps`` fused LB timesteps in one executable.

    The xla_extension 0.5.1 PJRT wrapper returns tuple results as a single
    tuple buffer, so chaining device-resident state across launches would
    need a host round-trip per step; fusing k steps into one launch
    amortises the host<->target transfer exactly like the paper keeps the
    master copy resident on the target (DESIGN.md section 2).
    """
    def body(_, carry):
        f, g = carry
        return full_step(f, g, lattice=lattice, vvl_block=vvl_block,
                         params=params)

    import jax
    return jax.lax.fori_loop(0, steps, body, (f_grid, g_grid))


def scale_field(field, *, a=1.5, vvl_block=256):
    """The paper's section-III example (quickstart artifact)."""
    return kernels.scale(field, a=a, vvl_block=vvl_block)
