"""AOT compile path: lower the L2/L1 entry points to HLO **text** artifacts.

Run once by ``make artifacts``; the Rust runtime (rust/src/runtime/) loads
the text with ``HloModuleProto::from_text_file``, compiles on the PJRT CPU
client and executes — Python is never on the request path.

HLO text (NOT ``lowered.compiler_ir('hlo')``/``.serialize()``) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that xla_extension 0.5.1 rejects; the text parser reassigns ids
(/opt/xla-example/README.md).

Every artifact is described in ``artifacts/manifest.json`` — name, entry
kind, lattice, shapes, vvl_block and the baked free-energy parameters — so
the Rust side never hard-codes shapes and always uses the identical
constants (the copyConstantToTarget analog is "baked at AOT time").
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax

jax.config.update("jax_enable_x64", True)  # before any tracing

import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import ref  # noqa: E402

F64 = "f64"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    IMPORTANT: the default HLO text printer elides large array constants as
    ``constant({...})``, which the downstream text parser silently turns
    into ZEROS — the per-velocity projection tables inside the collision
    kernel would vanish. ``print_large_constants`` keeps them verbatim
    (pinned by tests/test_aot.py and the Rust xla_parity tests).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the 0.5.1 text parser rejects newer metadata attributes
    # (source_end_line etc.), so strip metadata entirely
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), np.float64)


def _io(shapes):
    return [{"shape": list(s), "dtype": F64} for s in shapes]


@dataclasses.dataclass
class Artifact:
    name: str
    kind: str               # collision | full_step | gradient | scale
    lattice: str | None
    vvl_block: int
    inputs: list
    outputs: list
    extra: dict
    hlo: str

    def manifest_entry(self):
        return {
            "name": self.name,
            "file": f"{self.name}.hlo.txt",
            "kind": self.kind,
            "lattice": self.lattice,
            "vvl_block": self.vvl_block,
            "inputs": self.inputs,
            "outputs": self.outputs,
            **self.extra,
        }


def build_collision(lattice: str, n: int, vvl_block: int,
                    params: ref.FreeEnergyParams) -> Artifact:
    nvel = ref.velocity_set(lattice)[0].shape[0]
    shapes = [(nvel, n), (nvel, n), (3, n), (n,)]

    def fn(f, g, grad, lap):
        return model.collision_step(f, g, grad, lap, lattice=lattice,
                                    vvl_block=vvl_block, params=params)

    lowered = jax.jit(fn).lower(*map(spec, shapes))
    name = f"collision_{lattice}_n{n}_vvl{vvl_block}"
    return Artifact(name, "collision", lattice, vvl_block,
                    _io(shapes), _io([(nvel, n), (nvel, n)]),
                    {"n_sites": n, "nvel": nvel,
                     "params": dataclasses.asdict(params)},
                    to_hlo_text(lowered))


def build_full_step(lattice: str, grid, vvl_block: int,
                    params: ref.FreeEnergyParams) -> Artifact:
    nvel = ref.velocity_set(lattice)[0].shape[0]
    gshape = (nvel, *grid)

    def fn(f, g):
        return model.full_step(f, g, lattice=lattice,
                               vvl_block=vvl_block, params=params)

    lowered = jax.jit(fn).lower(spec(gshape), spec(gshape))
    name = f"full_step_{lattice}_{'x'.join(map(str, grid))}_vvl{vvl_block}"
    return Artifact(name, "full_step", lattice, vvl_block,
                    _io([gshape, gshape]), _io([gshape, gshape]),
                    {"grid": list(grid), "nvel": nvel,
                     "n_sites": int(np.prod(grid)),
                     "params": dataclasses.asdict(params)},
                    to_hlo_text(lowered))


def build_multi_step(lattice: str, grid, steps: int, vvl_block: int,
                     params: ref.FreeEnergyParams) -> Artifact:
    nvel = ref.velocity_set(lattice)[0].shape[0]
    gshape = (nvel, *grid)

    def fn(f, g):
        return model.multi_step(f, g, steps=steps, lattice=lattice,
                                vvl_block=vvl_block, params=params)

    lowered = jax.jit(fn).lower(spec(gshape), spec(gshape))
    name = (f"multi_step{steps}_{lattice}_"
            f"{'x'.join(map(str, grid))}_vvl{vvl_block}")
    return Artifact(name, "multi_step", lattice, vvl_block,
                    _io([gshape, gshape]), _io([gshape, gshape]),
                    {"grid": list(grid), "nvel": nvel, "steps": steps,
                     "n_sites": int(np.prod(grid)),
                     "params": dataclasses.asdict(params)},
                    to_hlo_text(lowered))


def build_gradient(grid) -> Artifact:
    gshape = tuple(grid)
    lowered = jax.jit(model.gradient_step).lower(spec(gshape))
    name = f"gradient_{'x'.join(map(str, grid))}"
    return Artifact(name, "gradient", None, 0,
                    _io([gshape]), _io([(3, *gshape), gshape]),
                    {"grid": list(grid), "n_sites": int(np.prod(grid))},
                    to_hlo_text(lowered))


def build_reduce(ncomp: int, n: int) -> Artifact:
    """Per-component lattice sum — the paper's section-V reduction
    extension, as an XLA artifact (kind "reduce")."""
    import jax.numpy as jnp

    def fn(x):
        return (jnp.sum(x, axis=1),)

    lowered = jax.jit(fn).lower(spec((ncomp, n)))
    name = f"reduce_sum_c{ncomp}_n{n}"
    return Artifact(name, "reduce", None, 0,
                    _io([(ncomp, n)]), _io([(ncomp,)]),
                    {"n_sites": n, "ncomp": ncomp},
                    to_hlo_text(lowered))


def build_scale(n: int, vvl_block: int, a: float) -> Artifact:
    def fn(x):
        return (model.scale_field(x, a=a, vvl_block=vvl_block),)

    lowered = jax.jit(fn).lower(spec((3, n)))
    name = f"scale_n{n}_vvl{vvl_block}"
    return Artifact(name, "scale", None, vvl_block,
                    _io([(3, n)]), _io([(3, n)]),
                    {"n_sites": n, "a": a},
                    to_hlo_text(lowered))


def default_artifacts(quick: bool) -> list:
    p = ref.FreeEnergyParams()
    arts = [
        build_scale(4096, 256, 1.5),
        # test-sized collision kernels, both lattices
        build_collision("d3q19", 4096, 256, p),
        build_collision("d2q9", 1024, 128, p),
        # end-to-end steps
        build_full_step("d3q19", (16, 16, 16), 256, p),
        build_full_step("d2q9", (64, 64, 1), 256, p),
        build_multi_step("d3q19", (16, 16, 16), 10, 256, p),
        build_gradient((16, 16, 16)),
        build_reduce(19, 4096),
        build_reduce(1, 4096),
        build_reduce(19, 32 * 32 * 32),
    ]
    if not quick:
        # E1/E2: Figure-1 benchmark size (32^3) with the vvl_block sweep —
        # the GPU-side VVL analog (DESIGN.md section 3). Blocks beyond 1024
        # added during the perf pass (EXPERIMENTS.md §Perf P5): on the
        # interpret-mode substrate the per-grid-step loop overhead
        # dominates, so fewer/larger blocks win monotonically.
        for blk in (32, 64, 128, 256, 512, 1024, 2048, 4096):
            arts.append(build_collision("d3q19", 32 * 32 * 32, blk, p))
        # fused steps use a large block for the same reason (P5)
        arts.append(build_full_step("d3q19", (32, 32, 32), 1024, p))
        arts.append(build_multi_step("d3q19", (32, 32, 32), 10, 1024, p))
        arts.append(build_multi_step("d2q9", (64, 64, 1), 10, 1024, p))
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="skip the benchmark-sized artifacts")
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    t0 = time.time()
    manifest = []
    for art in default_artifacts(args.quick):
        path = out / f"{art.name}.hlo.txt"
        path.write_text(art.hlo)
        manifest.append(art.manifest_entry())
        print(f"  wrote {path} ({len(art.hlo)} chars)")
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"{len(manifest)} artifacts + manifest.json in {out} "
          f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
